//! δ-MBST baseline (Marfoq et al.): degree-bounded minimum spanning tree.
//! Bounding the degree caps the Eq. 3 capacity division at hot nodes,
//! trading tree weight for per-link throughput.

use super::{RoundPlan, TopologyDesign};
use crate::graph::{degree_bounded_mst, degree_bounded_mst_dense, Graph};
use crate::net::{DatasetProfile, NetworkSpec};

/// Paper/Marfoq default degree bound.
pub const DEFAULT_DELTA: usize = 3;

/// Static δ-MBST design: every round is the all-strong degree-bounded
/// MST.
pub struct DeltaMbstTopology {
    overlay: Graph,
    delta: usize,
}

impl DeltaMbstTopology {
    /// Degree-bounded greedy over the dense connectivity slab (cached
    /// row minima) — byte-identical to [`Self::new_reference`],
    /// large-N viable.
    pub fn new(net: &NetworkSpec, profile: &DatasetProfile, delta: usize) -> Self {
        let conn = net.connectivity_dense(profile);
        DeltaMbstTopology { overlay: degree_bounded_mst_dense(&conn, delta), delta }
    }

    /// Pre-overhaul construction over the sparse complete [`Graph`],
    /// kept as the dense path's byte-identity oracle.
    pub fn new_reference(net: &NetworkSpec, profile: &DatasetProfile, delta: usize) -> Self {
        let conn = net.connectivity_graph(profile);
        DeltaMbstTopology { overlay: degree_bounded_mst(&conn, delta), delta }
    }

    /// The degree bound δ this tree was built under.
    pub fn delta(&self) -> usize {
        self.delta
    }
}

impl TopologyDesign for DeltaMbstTopology {
    fn name(&self) -> &str {
        "delta_mbst"
    }

    fn overlay(&self) -> &Graph {
        &self.overlay
    }

    fn plan(&mut self, _k: usize) -> RoundPlan {
        RoundPlan::all_strong(&self.overlay)
    }

    fn plan_into(&mut self, _k: usize, out: &mut RoundPlan) {
        RoundPlan::all_strong_into(&self.overlay, out);
    }

    /// The degree-bounded MST heuristic is deterministic in
    /// (network, profile, δ).
    fn seed_sensitive(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::zoo;

    #[test]
    fn degree_bound_holds_on_all_networks() {
        let p = DatasetProfile::femnist();
        for net in zoo::all_networks() {
            let t = DeltaMbstTopology::new(&net, &p, DEFAULT_DELTA);
            assert!(t.overlay().is_connected(), "{}", net.name);
            assert_eq!(t.overlay().edges().len(), net.n() - 1);
            for i in 0..net.n() {
                assert!(
                    t.overlay().degree(i) <= DEFAULT_DELTA,
                    "{}: deg({i}) = {}",
                    net.name,
                    t.overlay().degree(i)
                );
            }
        }
    }

    #[test]
    fn max_degree_below_plain_mst_hub() {
        // On Gaia the plain MST concentrates at a hub; δ-MBST must not.
        let net = zoo::gaia();
        let p = DatasetProfile::femnist();
        let mbst = DeltaMbstTopology::new(&net, &p, DEFAULT_DELTA);
        let max_deg = (0..net.n()).map(|i| mbst.overlay().degree(i)).max().unwrap();
        assert!(max_deg <= DEFAULT_DELTA);
    }

    #[test]
    fn dense_build_matches_reference_on_zoo() {
        let p = DatasetProfile::femnist();
        for net in [zoo::gaia(), zoo::amazon()] {
            for delta in [2usize, 3, 4] {
                let dense = DeltaMbstTopology::new(&net, &p, delta);
                let reference = DeltaMbstTopology::new_reference(&net, &p, delta);
                let (a, b) = (dense.overlay().edges(), reference.overlay().edges());
                assert_eq!(a.len(), b.len(), "{} delta={delta}", net.name);
                for (x, y) in a.iter().zip(b) {
                    assert_eq!(
                        (x.u, x.v, x.w.to_bits()),
                        (y.u, y.v, y.w.to_bits()),
                        "{} delta={delta}",
                        net.name
                    );
                }
            }
        }
    }
}
