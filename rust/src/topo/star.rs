//! STAR baseline: a central orchestrator averages all models every round
//! (client-server FedAvg topology). The hub is chosen to minimize the
//! worst silo↔hub delay (the betweenness-flavoured choice of [3]).

use super::{RoundPlan, TopologyDesign};
use crate::graph::Graph;
use crate::net::{DatasetProfile, NetworkSpec};

/// Static STAR design: every round every silo exchanges with the hub.
pub struct StarTopology {
    overlay: Graph,
    hub: usize,
}

impl StarTopology {
    /// Hub = argmin over candidates of max one-way latency to any silo.
    ///
    /// Each candidate's worst latency is computed once (O(N²) total);
    /// the reference recomputed both sides' O(N) scans inside the
    /// `min_by` comparator — ~4·N² haversines, ruinous at large N. The
    /// comparator sees the same values, so the hub (and overlay) is
    /// byte-identical to [`Self::new_reference`].
    pub fn new(net: &NetworkSpec, _profile: &DatasetProfile) -> Self {
        let n = net.n();
        assert!(n >= 2);
        let worst: Vec<f64> = (0..n)
            .map(|h| {
                (0..n).filter(|&i| i != h).map(|i| net.latency_ms(i, h)).fold(0.0, f64::max)
            })
            .collect();
        let hub = (0..n).min_by(|&a, &b| worst[a].total_cmp(&worst[b])).unwrap();
        Self::with_hub(net, hub)
    }

    /// Pre-overhaul construction (per-comparison latency scans), kept
    /// as the retuned path's byte-identity oracle.
    pub fn new_reference(net: &NetworkSpec, _profile: &DatasetProfile) -> Self {
        let n = net.n();
        assert!(n >= 2);
        let hub = (0..n)
            .min_by(|&a, &b| {
                let worst = |h: usize| {
                    (0..n)
                        .filter(|&i| i != h)
                        .map(|i| net.latency_ms(i, h))
                        .fold(0.0, f64::max)
                };
                worst(a).total_cmp(&worst(b))
            })
            .unwrap();
        Self::with_hub(net, hub)
    }

    fn with_hub(net: &NetworkSpec, hub: usize) -> Self {
        let n = net.n();
        let mut overlay = Graph::new(n);
        for i in 0..n {
            if i != hub {
                overlay.add_edge(hub, i, net.latency_ms(hub, i));
            }
        }
        StarTopology { overlay, hub }
    }

    /// The chosen hub silo.
    pub fn hub(&self) -> usize {
        self.hub
    }
}

impl TopologyDesign for StarTopology {
    fn name(&self) -> &str {
        "star"
    }

    fn overlay(&self) -> &Graph {
        &self.overlay
    }

    fn plan(&mut self, _k: usize) -> RoundPlan {
        RoundPlan::all_strong(&self.overlay)
    }

    fn plan_into(&mut self, _k: usize, out: &mut RoundPlan) {
        RoundPlan::all_strong_into(&self.overlay, out);
    }

    /// Hub choice and plans are pure functions of the network.
    fn seed_sensitive(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::zoo;

    #[test]
    fn star_has_n_minus_1_edges_through_hub() {
        let net = zoo::gaia();
        let s = StarTopology::new(&net, &DatasetProfile::femnist());
        assert_eq!(s.overlay().edges().len(), net.n() - 1);
        assert_eq!(s.overlay().degree(s.hub()), net.n() - 1);
        for i in 0..net.n() {
            if i != s.hub() {
                assert_eq!(s.overlay().degree(i), 1);
            }
        }
    }

    #[test]
    fn hub_is_centrally_located() {
        // For Gaia's region set the minimax hub must be a northern-
        // hemisphere site, not Sydney or São Paulo.
        let net = zoo::gaia();
        let s = StarTopology::new(&net, &DatasetProfile::femnist());
        let name = &net.silos[s.hub()].name;
        assert!(name != "sydney" && name != "sao_paulo", "hub = {name}");
    }

    #[test]
    fn plan_is_static_all_strong() {
        let net = zoo::gaia();
        let mut s = StarTopology::new(&net, &DatasetProfile::femnist());
        let p0 = s.plan(0);
        let p9 = s.plan(9);
        assert_eq!(p0.edges.len(), p9.edges.len());
        assert!(p0.isolated_nodes().is_empty());
        assert_eq!(s.period(), Some(1));
    }

    #[test]
    fn precomputed_hub_matches_reference_on_zoo() {
        let p = DatasetProfile::femnist();
        for net in [zoo::gaia(), zoo::ebone()] {
            let fast = StarTopology::new(&net, &p);
            let reference = StarTopology::new_reference(&net, &p);
            assert_eq!(fast.hub(), reference.hub(), "{}", net.name);
            let (a, b) = (fast.overlay().edges(), reference.overlay().edges());
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(b) {
                assert_eq!((x.u, x.v, x.w.to_bits()), (y.u, y.v, y.w.to_bits()), "{}", net.name);
            }
        }
    }
}
