//! Search candidates: an arbitrary connected overlay run through the
//! paper's own pipeline (Algorithm 1 → Algorithm 2).
//!
//! `mgfl optimize` mutates overlays (ring re-orderings plus chord
//! edges) and needs each mutant to behave exactly like a hand-built
//! design: same multigraph construction, same closed-form schedule,
//! same engine dispatch. [`CandidateTopology`] therefore does not
//! reimplement anything — it builds a [`Multigraph`] over the mutated
//! overlay and delegates every [`TopologyDesign`] method to the inner
//! [`MultigraphTopology`], so Algorithm 2's structure (and with it the
//! period/factorization contracts the compiled and factored engines
//! rely on) is preserved by construction.

use super::states::MultigraphTopology;
use super::{Multigraph, RoundPlan, ScheduleFactorization, TopologyDesign};
use crate::graph::Graph;
use crate::net::{DatasetProfile, NetworkSpec};

/// A searched topology: a caller-supplied overlay (any connected simple
/// graph over the network's silos) parsed into a multigraph schedule by
/// the paper's Algorithms 1 and 2.
///
/// The name reported in summaries is `"candidate"`, so search artifacts
/// are distinguishable from the paper's `"multigraph"` design even when
/// a candidate happens to reproduce the paper overlay exactly.
pub struct CandidateTopology {
    inner: MultigraphTopology,
}

impl CandidateTopology {
    /// Run Algorithm 1 (edge multiplicities, capped at `t`) and
    /// Algorithm 2 (the closed-form state schedule) over `overlay`.
    ///
    /// Panics (via [`Multigraph::construct`]) if the overlay is
    /// disconnected or its node count does not match the network.
    pub fn new(overlay: Graph, net: &NetworkSpec, profile: &DatasetProfile, t: u32) -> Self {
        let mg = Multigraph::construct(&overlay, net, profile, t);
        CandidateTopology { inner: MultigraphTopology::new(overlay, mg) }
    }

    /// The parsed multigraph (Algorithm 1's output).
    pub fn multigraph(&self) -> &Multigraph {
        self.inner.multigraph()
    }

    /// Schedule period (LCM of edge multiplicities).
    pub fn s_max(&self) -> u64 {
        self.inner.s_max()
    }
}

impl TopologyDesign for CandidateTopology {
    fn name(&self) -> &str {
        "candidate"
    }

    fn overlay(&self) -> &Graph {
        self.inner.overlay()
    }

    fn plan(&mut self, k: usize) -> RoundPlan {
        self.inner.plan(k)
    }

    fn plan_into(&mut self, k: usize, out: &mut RoundPlan) {
        self.inner.plan_into(k, out);
    }

    fn period(&self) -> Option<u64> {
        self.inner.period()
    }

    /// Delegated: the mutated overlay still parses to "pair (u, v)
    /// strong iff `k % n(u,v) == 0`", so the factored engine applies to
    /// candidates with huge s_max exactly as it does to the paper
    /// design.
    fn factorization(&self) -> Option<ScheduleFactorization> {
        self.inner.factorization()
    }

    /// Candidates are pure functions of (overlay, network, profile, t):
    /// the search RNG chooses *which* candidate to build, but a built
    /// candidate consumes no randomness.
    fn seed_sensitive(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{zoo, DatasetProfile};
    use crate::simtime::{simulate_summary, simulate_summary_naive};

    /// Overlay identical to the paper's RING construction, built the
    /// way the search builds genomes: consecutive cycle pairs.
    fn paper_overlay(net: &NetworkSpec, profile: &DatasetProfile) -> Graph {
        let cycle = crate::graph::christofides_cycle_dense(&net.connectivity_dense(profile));
        let mut g = Graph::new(net.n());
        for w in 0..cycle.len() {
            let (a, b) = (cycle[w], cycle[(w + 1) % cycle.len()]);
            g.add_edge(a, b, net.conn_weight(profile, a, b));
        }
        g
    }

    #[test]
    fn candidate_over_paper_overlay_matches_multigraph_bitwise() {
        let net = zoo::gaia();
        let p = DatasetProfile::femnist();
        let mut cand = CandidateTopology::new(paper_overlay(&net, &p), &net, &p, 5);
        let mut paper = MultigraphTopology::from_network(&net, &p, 5);
        assert_eq!(cand.s_max(), paper.s_max());
        assert_eq!(cand.multigraph().edges, paper.multigraph().edges);
        let a = simulate_summary(&mut cand, &net, &p, 240);
        let b = simulate_summary(&mut paper, &net, &p, 240);
        assert_eq!(a.total_ms.to_bits(), b.total_ms.to_bits());
        assert_eq!(a.mean_cycle_ms.to_bits(), b.mean_cycle_ms.to_bits());
        assert_eq!(a.topology, "candidate");
        assert_eq!(b.topology, "multigraph");
    }

    #[test]
    fn candidate_engines_match_naive_oracle() {
        // A mutated overlay (re-ordered ring + one chord) must stay
        // bit-identical between the dispatched engine and the naive
        // DelayTracker reference — the contract the search fitness
        // numbers rest on.
        let net = zoo::gaia();
        let p = DatasetProfile::femnist();
        let order = [0usize, 4, 6, 3, 7, 2, 1, 5, 9, 10, 8];
        let build = || {
            let mut g = Graph::new(net.n());
            for w in 0..order.len() {
                let (a, b) = (order[w], order[(w + 1) % order.len()]);
                g.add_edge(a, b, net.conn_weight(&p, a, b));
            }
            g.add_edge(4, 10, net.conn_weight(&p, 4, 10));
            CandidateTopology::new(g, &net, &p, 10)
        };
        let fast = simulate_summary(&mut build(), &net, &p, 300);
        let naive = simulate_summary_naive(&mut build(), &net, &p, 300);
        assert_eq!(fast.total_ms.to_bits(), naive.total_ms.to_bits());
        assert_eq!(fast.mean_cycle_ms.to_bits(), naive.mean_cycle_ms.to_bits());
        assert_eq!(fast.rounds_with_isolated, naive.rounds_with_isolated);
        assert_eq!(fast.max_isolated, naive.max_isolated);
    }

    #[test]
    fn candidate_contracts() {
        let net = zoo::gaia();
        let p = DatasetProfile::femnist();
        let cand = CandidateTopology::new(paper_overlay(&net, &p), &net, &p, 5);
        assert!(!cand.seed_sensitive());
        assert_eq!(cand.period(), Some(cand.s_max()));
        let f = cand.factorization().expect("candidates factorize");
        assert_eq!(f.edges.len(), cand.multigraph().edges.len());
    }
}
