//! Topology designs: the paper's multigraph plus every baseline from
//! Table 1 (STAR, MATCHA, MATCHA+, MST, δ-MBST, RING).
//!
//! A design produces a [`RoundPlan`] per communication round: the set of
//! undirected silo pairs that communicate, each marked strong (both ends
//! wait) or weak (asynchronous, nobody waits). Static baselines emit the
//! same all-strong plan every round; MATCHA samples matchings; the
//! multigraph cycles through its parsed states.

pub mod candidate;
pub mod delta_mbst;
pub mod masked;
pub mod matcha;
pub mod mst;
pub mod multigraph;
pub mod ring;
pub mod star;
pub mod states;

use crate::delay::EdgeType;
use crate::graph::{Graph, NodeId};

pub use candidate::CandidateTopology;
pub use masked::MaskedTopology;
pub use multigraph::Multigraph;
pub use states::{GraphState, MultigraphTopology};

/// The communication plan for one round.
#[derive(Debug, Clone)]
pub struct RoundPlan {
    /// Silo count (node ids in `edges` are `< n`).
    pub n: usize,
    /// Undirected pairs (u < v) with their connection type; communication
    /// happens in both directions over a pair.
    pub edges: Vec<(NodeId, NodeId, EdgeType)>,
}

impl Default for RoundPlan {
    /// An empty zero-node plan (scratch-pool seeding; retargeted by
    /// [`Self::reset`] before use).
    fn default() -> Self {
        RoundPlan::empty(0)
    }
}

impl RoundPlan {
    /// Build a plan, checking (in debug builds) that every pair is
    /// normalized `u < v` — the invariant the delay tracker's pair keys
    /// and the compiled engine's edge arena both rely on.
    pub fn new(n: usize, edges: Vec<(NodeId, NodeId, EdgeType)>) -> Self {
        if cfg!(debug_assertions) {
            for &(u, v, _) in &edges {
                debug_assert!(u < v, "RoundPlan pair must be normalized u < v, got ({u}, {v})");
            }
        }
        RoundPlan { n, edges }
    }

    /// An empty plan over `n` nodes, for reuse via [`Self::reset`] and
    /// [`Self::push`] (the `plan_into` zero-allocation path).
    pub fn empty(n: usize) -> Self {
        RoundPlan { n, edges: Vec::new() }
    }

    /// Clear the edge list (keeping its capacity) and retarget `n`.
    pub fn reset(&mut self, n: usize) {
        self.n = n;
        self.edges.clear();
    }

    /// Append one pair, asserting normalization in debug builds.
    #[inline]
    pub fn push(&mut self, u: NodeId, v: NodeId, ty: EdgeType) {
        debug_assert!(u < v, "RoundPlan pair must be normalized u < v, got ({u}, {v})");
        self.edges.push((u, v, ty));
    }

    /// Every edge of `g` marked strong — the plan of all static
    /// baselines (STAR, MST, δ-MBST, RING).
    pub fn all_strong(g: &Graph) -> Self {
        let mut plan = RoundPlan::empty(g.n());
        Self::all_strong_into(g, &mut plan);
        plan
    }

    /// Fill `out` with every edge of `g` marked strong, reusing its
    /// allocation.
    pub fn all_strong_into(g: &Graph, out: &mut RoundPlan) {
        out.reset(g.n());
        for e in g.edges() {
            out.push(e.u, e.v, EdgeType::Strong);
        }
    }

    /// Per-node degree over *all* planned edges (strong + weak) — the
    /// concurrency that divides access capacity in Eq. 3.
    pub fn degrees(&self) -> Vec<usize> {
        let mut deg = Vec::new();
        self.degrees_into(&mut deg);
        deg
    }

    /// Like [`Self::degrees`] but reusing `out` (no per-round allocation).
    pub fn degrees_into(&self, out: &mut Vec<usize>) {
        out.clear();
        out.resize(self.n, 0);
        for &(u, v, _) in &self.edges {
            out[u] += 1;
            out[v] += 1;
        }
    }

    /// Nodes participating in no strong edge this round. For the
    /// multigraph these are exactly the paper's *isolated nodes* (all
    /// incident connections weak); for baselines, nodes the design simply
    /// leaves out this round (e.g. MATCHA non-matched nodes).
    pub fn isolated_nodes(&self) -> Vec<NodeId> {
        let mut has_edge = Vec::new();
        let mut has_strong = Vec::new();
        self.mark_participation(&mut has_edge, &mut has_strong);
        (0..self.n).filter(|&i| has_edge[i] && !has_strong[i]).collect()
    }

    /// Mark, per node, whether it touches any planned edge / any strong
    /// edge. This is the single definition of the isolation rule —
    /// [`Self::isolated_nodes`], [`Self::isolated_count_into`], and
    /// through them both simulation engines all derive from it.
    pub fn mark_participation(&self, has_edge: &mut Vec<bool>, has_strong: &mut Vec<bool>) {
        has_edge.clear();
        has_edge.resize(self.n, false);
        has_strong.clear();
        has_strong.resize(self.n, false);
        for &(u, v, t) in &self.edges {
            has_edge[u] = true;
            has_edge[v] = true;
            if t == EdgeType::Strong {
                has_strong[u] = true;
                has_strong[v] = true;
            }
        }
    }

    /// `isolated_nodes().len()` without the id vec, into caller scratch
    /// (the compiled engine's per-round isolation count).
    pub fn isolated_count_into(
        &self,
        has_edge: &mut Vec<bool>,
        has_strong: &mut Vec<bool>,
    ) -> usize {
        self.mark_participation(has_edge, has_strong);
        (0..self.n).filter(|&i| has_edge[i] && !has_strong[i]).count()
    }

    /// The strongly-connected pairs of this plan, in plan order.
    pub fn strong_edges(&self) -> impl Iterator<Item = (NodeId, NodeId)> + '_ {
        self.edges
            .iter()
            .filter(|&&(_, _, t)| t == EdgeType::Strong)
            .map(|&(u, v, _)| (u, v))
    }
}

/// A period-factorized description of a periodic schedule: every listed
/// pair appears in **every** round's plan, strong exactly when
/// `k % multiplicity == 0` and weak otherwise.
///
/// This is the closed form Algorithm 2 proves for the parsed multigraph
/// (see [`states::edge_type_in_state`]): a pair with multiplicity n is
/// strong in states `s ≡ 0 (mod n)`, and since every n divides s_max,
/// `(k % s_max) % n == k % n` — the per-edge pattern is periodic in the
/// round index itself, with period n. The factored simulation engine
/// ([`crate::simtime::factored`]) exploits this to collapse the O(E)
/// per-round edge walk into O(distinct multiplicities) group updates,
/// which is what makes huge-s_max schedules (t = 30 has s_max ≈ 2.3e9)
/// cheap without materializing any states.
#[derive(Debug, Clone)]
pub struct ScheduleFactorization {
    /// Silo count (must match the overlay/network).
    pub n: usize,
    /// `(u, v, multiplicity)` with `u < v`, in plan order: `plan(k)`
    /// lists exactly these pairs, in this order, every round.
    pub edges: Vec<(NodeId, NodeId, u32)>,
}

/// A topology design consumed by the time simulator and the training
/// coordinator.
pub trait TopologyDesign {
    /// Short lowercase identifier used in summaries and artifacts
    /// (e.g. `"multigraph"`, `"matcha"`, `"candidate"`).
    fn name(&self) -> &str;

    /// The overlay graph: which pairs may ever communicate.
    fn overlay(&self) -> &Graph;

    /// The plan for round `k`. `&mut self` because stochastic designs
    /// (MATCHA) carry an RNG.
    fn plan(&mut self, k: usize) -> RoundPlan;

    /// Fill `out` with the plan for round `k`, reusing its allocation.
    /// This is the compiled engine's per-round entry point; every
    /// in-tree design overrides it allocation-free, and the default
    /// delegates to [`Self::plan`] for third-party designs.
    fn plan_into(&mut self, k: usize, out: &mut RoundPlan) {
        *out = self.plan(k);
    }

    /// Schedule period, if the design is periodic (multigraph: s_max;
    /// static designs: 1; stochastic: None).
    ///
    /// Contract: returning `Some(p)` asserts `plan(k)` depends only on
    /// `k % p` and consumes no randomness — the compiled engine
    /// enumerates states `0..p` once and replays them, and its cycle
    /// detector assumes the schedule recurs exactly. Stochastic designs
    /// must return `None`.
    fn period(&self) -> Option<u64> {
        Some(1)
    }

    /// Period-factorized view of the schedule, if the design can
    /// express one.
    ///
    /// Contract: returning `Some(f)` asserts that for **every** round
    /// `k`, `plan(k)` lists exactly `f.edges` (same pairs, same order),
    /// with pair `(u, v, m)` strong iff `k % m == 0` — so plan degrees
    /// are round-constant and the Eq. 4 recurrence factors into
    /// independent per-multiplicity groups. The factored engine
    /// ([`crate::simtime::factored`]) replays this closed form in
    /// O(distinct multiplicities) per round instead of walking edges; a
    /// wrong `Some` silently corrupts simulations, so the default is
    /// `None` (third-party designs stream).
    fn factorization(&self) -> Option<ScheduleFactorization> {
        None
    }

    /// Whether the experiment seed influences this design's behaviour.
    ///
    /// Contract: returning `false` asserts that two instances built
    /// from the same (network, profile, t) with *different* seeds emit
    /// identical plans for every round — construction consumes no
    /// randomness and `plan(k)` draws none. The sweep engine's
    /// work-deduplication layer merges cells of such designs across the
    /// seed axis, so a wrong `false` here silently collapses results;
    /// the default is therefore `true` (third-party designs are never
    /// merged unless they opt in). Kind-level mirror:
    /// [`crate::config::TopologyKind::seed_sensitive`], pinned equal to
    /// this method by a config test.
    fn seed_sensitive(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_plan_degrees_and_isolated() {
        let plan = RoundPlan {
            n: 4,
            edges: vec![
                (0, 1, EdgeType::Strong),
                (1, 2, EdgeType::Weak),
                (2, 3, EdgeType::Weak),
            ],
        };
        assert_eq!(plan.degrees(), vec![1, 2, 2, 1]);
        // 2 and 3 touch only weak edges -> isolated; 0,1 have strong.
        assert_eq!(plan.isolated_nodes(), vec![2, 3]);
        assert_eq!(plan.strong_edges().count(), 1);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "normalized")]
    fn push_rejects_unnormalized_pairs() {
        let mut plan = RoundPlan::empty(3);
        plan.push(2, 1, EdgeType::Strong);
    }

    #[test]
    fn degrees_into_reuses_buffer() {
        let plan = RoundPlan::new(3, vec![(0, 1, EdgeType::Strong), (1, 2, EdgeType::Weak)]);
        let mut buf = vec![9usize; 17]; // stale, oversized
        plan.degrees_into(&mut buf);
        assert_eq!(buf, vec![1, 2, 1]);
        assert_eq!(buf, plan.degrees());
    }

    #[test]
    fn all_strong_plan_has_no_isolated() {
        let g = Graph::from_edges(3, [(0, 1, 1.0), (1, 2, 1.0)]);
        let plan = RoundPlan::all_strong(&g);
        assert!(plan.isolated_nodes().is_empty());
        assert_eq!(plan.edges.len(), 2);
    }
}
