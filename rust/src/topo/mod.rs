//! Topology designs: the paper's multigraph plus every baseline from
//! Table 1 (STAR, MATCHA, MATCHA+, MST, δ-MBST, RING).
//!
//! A design produces a [`RoundPlan`] per communication round: the set of
//! undirected silo pairs that communicate, each marked strong (both ends
//! wait) or weak (asynchronous, nobody waits). Static baselines emit the
//! same all-strong plan every round; MATCHA samples matchings; the
//! multigraph cycles through its parsed states.

pub mod delta_mbst;
pub mod matcha;
pub mod mst;
pub mod multigraph;
pub mod ring;
pub mod star;
pub mod states;

use crate::delay::EdgeType;
use crate::graph::{Graph, NodeId};

pub use multigraph::Multigraph;
pub use states::{GraphState, MultigraphTopology};

/// The communication plan for one round.
#[derive(Debug, Clone)]
pub struct RoundPlan {
    pub n: usize,
    /// Undirected pairs (u < v) with their connection type; communication
    /// happens in both directions over a pair.
    pub edges: Vec<(NodeId, NodeId, EdgeType)>,
}

impl RoundPlan {
    pub fn all_strong(g: &Graph) -> Self {
        RoundPlan {
            n: g.n(),
            edges: g.edges().iter().map(|e| (e.u, e.v, EdgeType::Strong)).collect(),
        }
    }

    /// Per-node degree over *all* planned edges (strong + weak) — the
    /// concurrency that divides access capacity in Eq. 3.
    pub fn degrees(&self) -> Vec<usize> {
        let mut deg = vec![0usize; self.n];
        for &(u, v, _) in &self.edges {
            deg[u] += 1;
            deg[v] += 1;
        }
        deg
    }

    /// Nodes participating in no strong edge this round. For the
    /// multigraph these are exactly the paper's *isolated nodes* (all
    /// incident connections weak); for baselines, nodes the design simply
    /// leaves out this round (e.g. MATCHA non-matched nodes).
    pub fn isolated_nodes(&self) -> Vec<NodeId> {
        let mut has_strong = vec![false; self.n];
        let mut has_edge = vec![false; self.n];
        for &(u, v, t) in &self.edges {
            has_edge[u] = true;
            has_edge[v] = true;
            if t == EdgeType::Strong {
                has_strong[u] = true;
                has_strong[v] = true;
            }
        }
        (0..self.n).filter(|&i| has_edge[i] && !has_strong[i]).collect()
    }

    pub fn strong_edges(&self) -> impl Iterator<Item = (NodeId, NodeId)> + '_ {
        self.edges
            .iter()
            .filter(|&&(_, _, t)| t == EdgeType::Strong)
            .map(|&(u, v, _)| (u, v))
    }
}

/// A topology design consumed by the time simulator and the training
/// coordinator.
pub trait TopologyDesign {
    fn name(&self) -> &str;

    /// The overlay graph: which pairs may ever communicate.
    fn overlay(&self) -> &Graph;

    /// The plan for round `k`. `&mut self` because stochastic designs
    /// (MATCHA) carry an RNG.
    fn plan(&mut self, k: usize) -> RoundPlan;

    /// Schedule period, if the design is periodic (multigraph: s_max;
    /// static designs: 1; stochastic: None).
    fn period(&self) -> Option<u64> {
        Some(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_plan_degrees_and_isolated() {
        let plan = RoundPlan {
            n: 4,
            edges: vec![
                (0, 1, EdgeType::Strong),
                (1, 2, EdgeType::Weak),
                (2, 3, EdgeType::Weak),
            ],
        };
        assert_eq!(plan.degrees(), vec![1, 2, 2, 1]);
        // 2 and 3 touch only weak edges -> isolated; 0,1 have strong.
        assert_eq!(plan.isolated_nodes(), vec![2, 3]);
        assert_eq!(plan.strong_edges().count(), 1);
    }

    #[test]
    fn all_strong_plan_has_no_isolated() {
        let g = Graph::from_edges(3, [(0, 1, 1.0), (1, 2, 1.0)]);
        let plan = RoundPlan::all_strong(&g);
        assert!(plan.isolated_nodes().is_empty());
        assert_eq!(plan.edges.len(), 2);
    }
}
