//! RING baseline (Marfoq et al., NeurIPS'20): the Christofides ring over
//! the delay-weighted connectivity graph, used identically every round.
//! This is also the overlay the paper's multigraph is constructed from.

use super::{RoundPlan, TopologyDesign};
use crate::graph::{ring_overlay, ring_overlay_dense, Graph};
use crate::net::{DatasetProfile, NetworkSpec};

/// Static RING design: every round is the all-strong Christofides ring.
pub struct RingTopology {
    overlay: Graph,
}

impl RingTopology {
    /// Christofides ring over the dense connectivity slab — byte-
    /// identical to [`Self::new_reference`] (pinned by tests here and
    /// `benches/scaling.rs`), large-N viable.
    pub fn new(net: &NetworkSpec, profile: &DatasetProfile) -> Self {
        RingTopology { overlay: ring_overlay_dense(&net.connectivity_dense(profile)) }
    }

    /// Pre-overhaul construction over the sparse complete [`Graph`],
    /// kept as the dense path's byte-identity oracle.
    pub fn new_reference(net: &NetworkSpec, profile: &DatasetProfile) -> Self {
        let conn = net.connectivity_graph(profile);
        RingTopology { overlay: ring_overlay(&conn) }
    }

    /// Build from an existing overlay (used by ablations that remove
    /// silos from the RING overlay — paper Table 4).
    pub fn from_overlay(overlay: Graph) -> Self {
        RingTopology { overlay }
    }
}

impl TopologyDesign for RingTopology {
    fn name(&self) -> &str {
        "ring"
    }

    fn overlay(&self) -> &Graph {
        &self.overlay
    }

    fn plan(&mut self, _k: usize) -> RoundPlan {
        RoundPlan::all_strong(&self.overlay)
    }

    fn plan_into(&mut self, _k: usize, out: &mut RoundPlan) {
        RoundPlan::all_strong_into(&self.overlay, out);
    }

    /// The Christofides ring is deterministic in (network, profile).
    fn seed_sensitive(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::zoo;

    #[test]
    fn ring_degree_two_everywhere() {
        for net in [zoo::gaia(), zoo::amazon()] {
            let r = RingTopology::new(&net, &DatasetProfile::femnist());
            assert_eq!(r.overlay().edges().len(), net.n());
            for i in 0..net.n() {
                assert_eq!(r.overlay().degree(i), 2, "{} node {i}", net.name);
            }
            assert!(r.overlay().is_connected());
        }
    }

    #[test]
    fn ring_prefers_short_geo_hops() {
        // The Christofides ring over Gaia should be much shorter than a
        // random order: compare against the worst-case "zigzag" bound.
        let net = zoo::gaia();
        let p = DatasetProfile::femnist();
        let r = RingTopology::new(&net, &p);
        let conn = net.connectivity_graph(&p);
        let ring_len = r.overlay().total_weight();
        let max_edge = conn.edges().iter().map(|e| e.w).fold(0.0, f64::max);
        assert!(
            ring_len < max_edge * net.n() as f64 * 0.6,
            "ring {ring_len} not better than zigzag bound"
        );
    }

    #[test]
    fn dense_build_matches_reference_on_zoo() {
        let p = DatasetProfile::femnist();
        for net in [zoo::gaia(), zoo::exodus()] {
            let dense = RingTopology::new(&net, &p);
            let reference = RingTopology::new_reference(&net, &p);
            let (a, b) = (dense.overlay().edges(), reference.overlay().edges());
            assert_eq!(a.len(), b.len(), "{}", net.name);
            for (x, y) in a.iter().zip(b) {
                assert_eq!((x.u, x.v, x.w.to_bits()), (y.u, y.v, y.w.to_bits()), "{}", net.name);
            }
        }
    }
}
