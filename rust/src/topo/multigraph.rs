//! Algorithm 1 — Multigraph Construction (the paper's §4.1).
//!
//! From the RING overlay, each silo pair (i,j) is expanded into
//! `n(i,j) = min(t, round(d(i,j) / d_min))` parallel edges: exactly one
//! strongly-connected edge plus `n(i,j) - 1` weakly-connected edges.
//! Long-delay pairs therefore spend most states on weak edges, which is
//! what generates isolated nodes and cuts the Eq. 5 cycle time.

use crate::delay::eq3_delay_ms;
use crate::graph::{Graph, NodeId};
use crate::net::{DatasetProfile, NetworkSpec};

/// One overlay pair in the multigraph with its edge multiplicity.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MultiEdge {
    /// Lower endpoint of the pair (u < v).
    pub u: NodeId,
    /// Upper endpoint of the pair.
    pub v: NodeId,
    /// Symmetrized Eq. 3 overlay delay for this pair, ms.
    pub delay_ms: f64,
    /// n(i,j): total parallel edges (1 strong + n-1 weak).
    pub n_edges: u32,
}

/// The multigraph \(\mathcal{G}_m\) = overlay pairs + multiplicities
/// (the track list \(\mathcal{L}\) of Algorithm 1).
#[derive(Debug, Clone)]
pub struct Multigraph {
    /// Number of silos.
    pub n: usize,
    /// One entry per overlay pair, sorted by (u, v).
    pub edges: Vec<MultiEdge>,
    /// The maximum-edges parameter t of Algorithm 1.
    pub t: u32,
    /// min delay over overlay pairs (d_min), ms.
    pub d_min_ms: f64,
}

impl Multigraph {
    /// Algorithm 1. `overlay` must be connected; delays are computed with
    /// Eq. 3 using the overlay degrees (the paper's "delay computation
    /// for overlay" step). `t >= 1`.
    pub fn construct(
        overlay: &Graph,
        net: &NetworkSpec,
        profile: &DatasetProfile,
        t: u32,
    ) -> Self {
        assert!(t >= 1, "t must be >= 1 (t=1 degenerates to the overlay)");
        assert!(overlay.is_connected(), "overlay must be connected");
        assert_eq!(overlay.n(), net.n(), "overlay/network size mismatch");

        // Lines 1-4: delays for every overlay pair. The pair delay is the
        // max of the two directions (identical when capacities are
        // uniform, as in the paper's 10 Gbps setting).
        let delays: Vec<f64> = overlay
            .edges()
            .iter()
            .map(|e| {
                let d_uv =
                    eq3_delay_ms(net, profile, e.u, e.v, overlay.degree(e.u), overlay.degree(e.v));
                let d_vu =
                    eq3_delay_ms(net, profile, e.v, e.u, overlay.degree(e.v), overlay.degree(e.u));
                d_uv.max(d_vu)
            })
            .collect();

        // Line 5: d_min. Seed with +inf (not f64::MAX) so an empty edge
        // set can never masquerade as a real delay.
        let d_min_ms = delays.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(
            d_min_ms > 0.0 && d_min_ms.is_finite(),
            "d_min must be positive and finite on network '{}' (got {} over {} overlay pairs)",
            net.name,
            d_min_ms,
            delays.len()
        );

        // Lines 8-15: n(i,j) = min(t, round(d/d_min)), floored at 1 so
        // every pair keeps its strongly-connected edge.
        let edges = overlay
            .edges()
            .iter()
            .zip(&delays)
            .map(|(e, &d)| MultiEdge {
                u: e.u,
                v: e.v,
                delay_ms: d,
                n_edges: ((d / d_min_ms).round() as u32).clamp(1, t),
            })
            .collect();

        Multigraph { n: overlay.n(), edges, t, d_min_ms }
    }

    /// Total edges in the multiset \(\mathcal{E}_m\) (strong + weak).
    pub fn total_edges(&self) -> u64 {
        self.edges.iter().map(|e| e.n_edges as u64).sum()
    }

    /// Count of weakly-connected edges.
    pub fn weak_edges(&self) -> u64 {
        self.edges.iter().map(|e| (e.n_edges - 1) as u64).sum()
    }

    /// s_max: least common multiple of all n(i,j) (Algorithm 2 line 1).
    pub fn s_max(&self) -> u64 {
        self.edges
            .iter()
            .map(|e| e.n_edges as u64)
            .fold(1u64, crate::util::lcm)
    }

    /// Neighbour multiplicities per node: (neighbor, n_edges) lists.
    pub fn node_pairs(&self) -> Vec<Vec<(NodeId, u32)>> {
        let mut out = vec![Vec::new(); self.n];
        for e in &self.edges {
            out[e.u].push((e.v, e.n_edges));
            out[e.v].push((e.u, e.n_edges));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ring_overlay;
    use crate::net::zoo;

    fn gaia_multigraph(t: u32) -> Multigraph {
        let net = zoo::gaia();
        let p = DatasetProfile::femnist();
        let overlay = ring_overlay(&net.connectivity_graph(&p));
        Multigraph::construct(&overlay, &net, &p, t)
    }

    #[test]
    fn every_pair_has_one_strong_edge() {
        let mg = gaia_multigraph(5);
        for e in &mg.edges {
            assert!(e.n_edges >= 1, "pair ({},{}) lost its strong edge", e.u, e.v);
            assert!(e.n_edges <= 5);
        }
    }

    #[test]
    fn t_equals_one_degenerates_to_overlay() {
        // Paper Table 6: t=1 means no weak connections — pure RING.
        let mg = gaia_multigraph(1);
        assert!(mg.edges.iter().all(|e| e.n_edges == 1));
        assert_eq!(mg.weak_edges(), 0);
        assert_eq!(mg.s_max(), 1);
    }

    #[test]
    fn longer_delay_more_edges() {
        let mg = gaia_multigraph(5);
        let min_pair = mg.edges.iter().min_by(|a, b| a.delay_ms.total_cmp(&b.delay_ms)).unwrap();
        let max_pair = mg.edges.iter().max_by(|a, b| a.delay_ms.total_cmp(&b.delay_ms)).unwrap();
        assert_eq!(min_pair.n_edges, 1, "d_min pair must round to 1 edge");
        assert!(max_pair.n_edges >= min_pair.n_edges);
        // Gaia has >5x delay spread on its ring -> the max pair saturates t.
        assert_eq!(max_pair.n_edges, 5, "max-delay pair should hit t");
    }

    #[test]
    fn multiplicity_monotone_in_t() {
        let m3 = gaia_multigraph(3);
        let m8 = gaia_multigraph(8);
        for (a, b) in m3.edges.iter().zip(&m8.edges) {
            assert!(b.n_edges >= a.n_edges);
        }
        assert!(m8.weak_edges() >= m3.weak_edges());
    }

    #[test]
    fn s_max_divisible_by_all_multiplicities() {
        let mg = gaia_multigraph(5);
        let s = mg.s_max();
        for e in &mg.edges {
            assert_eq!(s % e.n_edges as u64, 0);
        }
        assert!(s <= 60, "LCM(1..=5) = 60 bound");
    }

    #[test]
    fn d_min_is_minimum() {
        let mg = gaia_multigraph(5);
        for e in &mg.edges {
            assert!(e.delay_ms >= mg.d_min_ms - 1e-12);
        }
    }

    #[test]
    #[should_panic(expected = "connected")]
    fn rejects_disconnected_overlay() {
        let net = zoo::gaia();
        let p = DatasetProfile::femnist();
        let g = Graph::new(net.n()); // no edges
        Multigraph::construct(&g, &net, &p, 5);
    }

    #[test]
    fn metro_clustered_networks_have_high_multiplicity() {
        // Exodus: sub-ms intra-metro pairs next to ~60ms cross-country
        // pairs -> many pairs saturate t (drives Table 3's isolated rate).
        let net = zoo::exodus();
        let p = DatasetProfile::femnist();
        let overlay = ring_overlay(&net.connectivity_graph(&p));
        let mg = Multigraph::construct(&overlay, &net, &p, 5);
        let saturated = mg.edges.iter().filter(|e| e.n_edges == 5).count();
        assert!(saturated > 0, "expected saturated pairs on exodus");
        assert!(mg.weak_edges() > 0);
    }
}
