//! MATCHA baseline (Wang et al.): decompose the communication graph into
//! matchings; every round, activate each matching independently so the
//! expected communication fraction equals a budget C_b.
//!
//! Interpretation notes (DESIGN.md §Substitutions): the original MATCHA
//! assumes a given base topology; following Marfoq et al.'s cross-silo
//! adaptation we build the base graph as MST ∪ Christofides-ring (a
//! sparse connected backbone with chordal diversity). `MATCHA(+)` is the
//! convergence-preserving variant that activates a *superset* fraction
//! (C_b = 1 reproduces the "wait for every matching" behaviour whose
//! cycle times Table 1 reports as MATCHA(+) ≥ MATCHA).

use super::{RoundPlan, TopologyDesign};
use crate::delay::EdgeType;
use crate::graph::{matching_decomposition, prim_mst, ring_overlay, Graph, NodeId};
use crate::net::{DatasetProfile, NetworkSpec};
use crate::util::Rng64;

/// Default MATCHA communication budget.
pub const DEFAULT_BUDGET: f64 = 0.5;

pub struct MatchaTopology {
    name: String,
    overlay: Graph,
    matchings: Vec<Vec<(NodeId, NodeId, f64)>>,
    /// Per-round activation probability of each matching.
    budget: f64,
    rng: Rng64,
}

impl MatchaTopology {
    pub fn new(net: &NetworkSpec, profile: &DatasetProfile, budget: f64, seed: u64) -> Self {
        assert!((0.0..=1.0).contains(&budget), "budget must be in [0,1]");
        let conn = net.connectivity_graph(profile);
        // Base graph: MST ∪ ring — connected, sparse, with enough edge
        // diversity for the decomposition to matter.
        let mst = prim_mst(&conn);
        let ring = ring_overlay(&conn);
        let mut overlay = Graph::new(net.n());
        let mut seen = std::collections::BTreeSet::new();
        for e in mst.edges().iter().chain(ring.edges()) {
            if seen.insert(e.pair()) {
                overlay.add_edge(e.u, e.v, e.w);
            }
        }
        let edge_list: Vec<_> = overlay.edges().iter().map(|e| (e.u, e.v, e.w)).collect();
        let matchings = matching_decomposition(&edge_list);
        let name = if budget >= 1.0 { "matcha_plus" } else { "matcha" };
        MatchaTopology {
            name: name.to_string(),
            overlay,
            matchings,
            budget,
            rng: Rng64::seed_from_u64(seed),
        }
    }

    /// The convergence-preserving full-activation variant.
    pub fn plus(net: &NetworkSpec, profile: &DatasetProfile, seed: u64) -> Self {
        Self::new(net, profile, 1.0, seed)
    }

    pub fn num_matchings(&self) -> usize {
        self.matchings.len()
    }
}

impl TopologyDesign for MatchaTopology {
    fn name(&self) -> &str {
        &self.name
    }

    fn overlay(&self) -> &Graph {
        &self.overlay
    }

    fn plan(&mut self, k: usize) -> RoundPlan {
        let mut plan = RoundPlan::empty(self.overlay.n());
        self.plan_into(k, &mut plan);
        plan
    }

    fn plan_into(&mut self, _k: usize, out: &mut RoundPlan) {
        out.reset(self.overlay.n());
        for m in &self.matchings {
            if self.budget >= 1.0 || self.rng.gen_f64() < self.budget {
                for &(u, v, _) in m {
                    out.push(u, v, EdgeType::Strong);
                }
            }
        }
    }

    fn period(&self) -> Option<u64> {
        if self.budget >= 1.0 {
            Some(1)
        } else {
            None // stochastic
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::zoo;

    #[test]
    fn matchings_partition_overlay() {
        let net = zoo::gaia();
        let m = MatchaTopology::new(&net, &DatasetProfile::femnist(), 0.5, 0);
        let total: usize = m.matchings.iter().map(|x| x.len()).sum();
        assert_eq!(total, m.overlay().edges().len());
        assert!(m.num_matchings() >= 2);
    }

    #[test]
    fn plan_respects_budget_in_expectation() {
        let net = zoo::gaia();
        let mut m = MatchaTopology::new(&net, &DatasetProfile::femnist(), 0.5, 42);
        let total_edges = m.overlay().edges().len();
        let rounds = 400;
        let mut active = 0usize;
        for k in 0..rounds {
            active += m.plan(k).edges.len();
        }
        let frac = active as f64 / (rounds * total_edges) as f64;
        assert!((0.4..0.6).contains(&frac), "activation fraction {frac}");
    }

    #[test]
    fn matcha_plus_activates_everything() {
        let net = zoo::gaia();
        let mut m = MatchaTopology::plus(&net, &DatasetProfile::femnist(), 0);
        let plan = m.plan(0);
        assert_eq!(plan.edges.len(), m.overlay().edges().len());
        assert_eq!(m.name(), "matcha_plus");
        assert_eq!(m.period(), Some(1));
    }

    #[test]
    fn every_plan_is_a_union_of_matchings() {
        // No node may appear twice within a single activated matching;
        // across matchings the node can repeat — check per-round degree
        // bounded by number of matchings.
        let net = zoo::amazon();
        let mut m = MatchaTopology::new(&net, &DatasetProfile::femnist(), 0.7, 7);
        let bound = m.num_matchings();
        for k in 0..50 {
            let plan = m.plan(k);
            let deg = plan.degrees();
            assert!(deg.iter().all(|&d| d <= bound));
        }
    }

    #[test]
    fn deterministic_under_seed() {
        let net = zoo::gaia();
        let p = DatasetProfile::femnist();
        let mut a = MatchaTopology::new(&net, &p, 0.5, 9);
        let mut b = MatchaTopology::new(&net, &p, 0.5, 9);
        for k in 0..20 {
            assert_eq!(a.plan(k).edges.len(), b.plan(k).edges.len());
        }
    }
}
