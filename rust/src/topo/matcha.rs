//! MATCHA baseline (Wang et al.): decompose the communication graph into
//! matchings; every round, activate each matching independently so the
//! expected communication fraction equals a budget C_b.
//!
//! Interpretation notes (DESIGN.md §Substitutions): the original MATCHA
//! assumes a given base topology; following Marfoq et al.'s cross-silo
//! adaptation we build the base graph as MST ∪ Christofides-ring (a
//! sparse connected backbone with chordal diversity). `MATCHA(+)` is the
//! convergence-preserving variant that activates a *superset* fraction
//! (C_b = 1 reproduces the "wait for every matching" behaviour whose
//! cycle times Table 1 reports as MATCHA(+) ≥ MATCHA).
//!
//! Construction is split from sampling: [`MatchaCore`] is the
//! seed-independent product (base graph + matching decomposition,
//! deterministic in (network, profile)), shareable via `Arc`;
//! [`MatchaTopology`] layers the per-experiment activation RNG on top.
//! The sweep engine's build-once cache exploits this — a seed axis of
//! N stochastic MATCHA cells pays for one Christofides/MST build, not N.

use std::sync::Arc;

use super::{RoundPlan, TopologyDesign};
use crate::delay::EdgeType;
use crate::graph::{
    matching_decomposition, prim_mst, prim_mst_dense, ring_overlay, ring_overlay_dense, Graph,
    NodeId,
};
use crate::net::{DatasetProfile, NetworkSpec};
use crate::util::Rng64;

/// Default MATCHA communication budget.
pub const DEFAULT_BUDGET: f64 = 0.5;

/// The seed-independent half of MATCHA: the MST ∪ ring base graph and
/// its matching decomposition. Plain immutable data (`Send + Sync`), so
/// one build serves every seed of a (network, profile) pair.
pub struct MatchaCore {
    overlay: Graph,
    matchings: Vec<Vec<(NodeId, NodeId, f64)>>,
}

impl MatchaCore {
    /// Base graph + decomposition over the dense connectivity slab —
    /// byte-identical to [`Self::build_reference`], large-N viable.
    pub fn build(net: &NetworkSpec, profile: &DatasetProfile) -> Self {
        let conn = net.connectivity_dense(profile);
        let mst = prim_mst_dense(&conn);
        let ring = ring_overlay_dense(&conn);
        Self::from_backbone(net.n(), &mst, &ring)
    }

    /// Pre-overhaul construction over the sparse complete [`Graph`],
    /// kept as the dense path's byte-identity oracle.
    pub fn build_reference(net: &NetworkSpec, profile: &DatasetProfile) -> Self {
        let conn = net.connectivity_graph(profile);
        let mst = prim_mst(&conn);
        let ring = ring_overlay(&conn);
        Self::from_backbone(net.n(), &mst, &ring)
    }

    /// Base graph: MST ∪ ring — connected, sparse, with enough edge
    /// diversity for the decomposition to matter. Shared by both
    /// construction paths (the substrate differs, the union and
    /// decomposition do not).
    fn from_backbone(n: usize, mst: &Graph, ring: &Graph) -> Self {
        let mut overlay = Graph::new(n);
        let mut seen = std::collections::BTreeSet::new();
        for e in mst.edges().iter().chain(ring.edges()) {
            if seen.insert(e.pair()) {
                overlay.add_edge(e.u, e.v, e.w);
            }
        }
        let edge_list: Vec<_> = overlay.edges().iter().map(|e| (e.u, e.v, e.w)).collect();
        let matchings = matching_decomposition(&edge_list);
        MatchaCore { overlay, matchings }
    }

    /// The MST ∪ ring base graph the matchings decompose.
    pub fn overlay(&self) -> &Graph {
        &self.overlay
    }

    /// The matching decomposition: disjoint `(u, v, w)` edge sets whose
    /// union is the overlay.
    pub fn matchings(&self) -> &[Vec<(NodeId, NodeId, f64)>] {
        &self.matchings
    }

    /// Number of matchings in the decomposition.
    pub fn num_matchings(&self) -> usize {
        self.matchings.len()
    }
}

/// MATCHA baseline: each round independently activates each matching of
/// the decomposed base graph with probability `budget` (MATCHA+ at
/// budget 1.0 activates everything).
pub struct MatchaTopology {
    name: String,
    core: Arc<MatchaCore>,
    /// Per-round activation probability of each matching.
    budget: f64,
    rng: Rng64,
}

impl MatchaTopology {
    /// Build the MST ∪ ring core for `net` and wrap it at `budget` with
    /// an activation RNG seeded from `seed`.
    pub fn new(net: &NetworkSpec, profile: &DatasetProfile, budget: f64, seed: u64) -> Self {
        Self::from_core(Arc::new(MatchaCore::build(net, profile)), budget, seed)
    }

    /// Instantiate over a shared (possibly cached) core. Bit-identical
    /// to [`Self::new`] with the core's (network, profile): the only
    /// per-instance state is the activation RNG.
    pub fn from_core(core: Arc<MatchaCore>, budget: f64, seed: u64) -> Self {
        assert!((0.0..=1.0).contains(&budget), "budget must be in [0,1]");
        let name = if budget >= 1.0 { "matcha_plus" } else { "matcha" };
        MatchaTopology {
            name: name.to_string(),
            core,
            budget,
            rng: Rng64::seed_from_u64(seed),
        }
    }

    /// The convergence-preserving full-activation variant.
    pub fn plus(net: &NetworkSpec, profile: &DatasetProfile, seed: u64) -> Self {
        Self::new(net, profile, 1.0, seed)
    }

    /// Number of matchings in the shared core's decomposition.
    pub fn num_matchings(&self) -> usize {
        self.core.num_matchings()
    }
}

impl TopologyDesign for MatchaTopology {
    fn name(&self) -> &str {
        &self.name
    }

    fn overlay(&self) -> &Graph {
        &self.core.overlay
    }

    fn plan(&mut self, k: usize) -> RoundPlan {
        let mut plan = RoundPlan::empty(self.core.overlay.n());
        self.plan_into(k, &mut plan);
        plan
    }

    fn plan_into(&mut self, _k: usize, out: &mut RoundPlan) {
        out.reset(self.core.overlay.n());
        // Borrow the core and the RNG disjointly: the matchings are
        // behind the shared Arc, the RNG is this instance's own.
        let MatchaTopology { core, budget, rng, .. } = self;
        for m in core.matchings() {
            if *budget >= 1.0 || rng.gen_f64() < *budget {
                for &(u, v, _) in m {
                    out.push(u, v, EdgeType::Strong);
                }
            }
        }
    }

    fn period(&self) -> Option<u64> {
        if self.budget >= 1.0 {
            Some(1)
        } else {
            None // stochastic
        }
    }

    /// Only the budget-limited variant draws randomness: at C_b = 1
    /// (MATCHA+) every matching activates unconditionally and the RNG
    /// is never consulted.
    fn seed_sensitive(&self) -> bool {
        self.budget < 1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::zoo;

    #[test]
    fn matchings_partition_overlay() {
        let net = zoo::gaia();
        let m = MatchaTopology::new(&net, &DatasetProfile::femnist(), 0.5, 0);
        let total: usize = m.core.matchings().iter().map(|x| x.len()).sum();
        assert_eq!(total, m.overlay().edges().len());
        assert!(m.num_matchings() >= 2);
    }

    #[test]
    fn plan_respects_budget_in_expectation() {
        let net = zoo::gaia();
        let mut m = MatchaTopology::new(&net, &DatasetProfile::femnist(), 0.5, 42);
        let total_edges = m.overlay().edges().len();
        let rounds = 400;
        let mut active = 0usize;
        for k in 0..rounds {
            active += m.plan(k).edges.len();
        }
        let frac = active as f64 / (rounds * total_edges) as f64;
        assert!((0.4..0.6).contains(&frac), "activation fraction {frac}");
    }

    #[test]
    fn matcha_plus_activates_everything() {
        let net = zoo::gaia();
        let mut m = MatchaTopology::plus(&net, &DatasetProfile::femnist(), 0);
        let plan = m.plan(0);
        assert_eq!(plan.edges.len(), m.overlay().edges().len());
        assert_eq!(m.name(), "matcha_plus");
        assert_eq!(m.period(), Some(1));
        assert!(!m.seed_sensitive(), "MATCHA+ consumes no randomness");
    }

    #[test]
    fn every_plan_is_a_union_of_matchings() {
        // No node may appear twice within a single activated matching;
        // across matchings the node can repeat — check per-round degree
        // bounded by number of matchings.
        let net = zoo::amazon();
        let mut m = MatchaTopology::new(&net, &DatasetProfile::femnist(), 0.7, 7);
        let bound = m.num_matchings();
        for k in 0..50 {
            let plan = m.plan(k);
            let deg = plan.degrees();
            assert!(deg.iter().all(|&d| d <= bound));
        }
    }

    #[test]
    fn deterministic_under_seed() {
        let net = zoo::gaia();
        let p = DatasetProfile::femnist();
        let mut a = MatchaTopology::new(&net, &p, 0.5, 9);
        let mut b = MatchaTopology::new(&net, &p, 0.5, 9);
        for k in 0..20 {
            assert_eq!(a.plan(k).edges.len(), b.plan(k).edges.len());
        }
    }

    #[test]
    fn shared_core_matches_fresh_construction() {
        // from_core over one Arc must be indistinguishable from new():
        // same overlay, same matchings, same sampled schedule per seed —
        // the invariant that lets the sweep cache share construction
        // across the seed axis.
        let net = zoo::gaia();
        let p = DatasetProfile::femnist();
        let core = Arc::new(MatchaCore::build(&net, &p));
        for seed in [3u64, 1234567] {
            let mut fresh = MatchaTopology::new(&net, &p, 0.5, seed);
            let mut shared = MatchaTopology::from_core(Arc::clone(&core), 0.5, seed);
            assert_eq!(fresh.overlay().edges().len(), shared.overlay().edges().len());
            for k in 0..40 {
                assert_eq!(fresh.plan(k).edges, shared.plan(k).edges, "seed {seed} round {k}");
            }
        }
        assert!(MatchaTopology::from_core(core, 0.5, 0).seed_sensitive());
    }

    #[test]
    fn dense_core_matches_reference_core() {
        let p = DatasetProfile::femnist();
        for net in [zoo::gaia(), zoo::geant()] {
            let dense = MatchaCore::build(&net, &p);
            let reference = MatchaCore::build_reference(&net, &p);
            let (a, b) = (dense.overlay().edges(), reference.overlay().edges());
            assert_eq!(a.len(), b.len(), "{}", net.name);
            for (x, y) in a.iter().zip(b) {
                assert_eq!((x.u, x.v, x.w.to_bits()), (y.u, y.v, y.w.to_bits()), "{}", net.name);
            }
            assert_eq!(dense.matchings(), reference.matchings(), "{}", net.name);
            // Same seed over either core → the same sampled schedule.
            let mut da = MatchaTopology::from_core(Arc::new(dense), 0.5, 42);
            let mut db = MatchaTopology::from_core(Arc::new(reference), 0.5, 42);
            for k in 0..20 {
                assert_eq!(da.plan(k).edges, db.plan(k).edges, "{} round {k}", net.name);
            }
        }
    }
}
