//! Experiment configuration: typed config model + a TOML-subset loader
//! (flat `key = value` pairs and `[section]` headers — all this project
//! needs, parsed in-tree since the offline build has no toml crate).

use std::path::Path;
use std::str::FromStr;

use anyhow::{bail, ensure, Context, Result};

/// Which topology design to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum TopologyKind {
    Star,
    Matcha,
    MatchaPlus,
    Mst,
    DeltaMbst,
    Ring,
    Multigraph,
}

impl TopologyKind {
    pub fn all() -> [TopologyKind; 7] {
        use TopologyKind::*;
        [Star, Matcha, MatchaPlus, Mst, DeltaMbst, Ring, Multigraph]
    }

    /// Whether the experiment seed influences the design this kind
    /// builds ([`ExperimentConfig::build_topology`]). Kind-level mirror
    /// of [`crate::topo::TopologyDesign::seed_sensitive`] — the sweep
    /// scheduler consults it *before* building anything, to decide
    /// whether cells differing only in seed are the same work item.
    /// Only budget-limited MATCHA draws randomness; MATCHA+ activates
    /// every matching unconditionally and all other designs are pure
    /// functions of (network, profile, t). Pinned equal to the built
    /// designs' own answer by `kind_contracts_match_built_designs`.
    pub fn seed_sensitive(&self) -> bool {
        matches!(self, TopologyKind::Matcha)
    }

    /// Whether Algorithm 1's `t` parameter reaches the design this kind
    /// builds. Every cell carries `t` for bookkeeping, but only the
    /// multigraph consumes it — the sweep compile cache collapses the
    /// `t` axis for every other kind.
    pub fn t_sensitive(&self) -> bool {
        matches!(self, TopologyKind::Multigraph)
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            TopologyKind::Star => "star",
            TopologyKind::Matcha => "matcha",
            TopologyKind::MatchaPlus => "matcha_plus",
            TopologyKind::Mst => "mst",
            TopologyKind::DeltaMbst => "delta_mbst",
            TopologyKind::Ring => "ring",
            TopologyKind::Multigraph => "multigraph",
        }
    }
}

impl FromStr for TopologyKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "star" => TopologyKind::Star,
            "matcha" => TopologyKind::Matcha,
            "matcha_plus" | "matcha+" => TopologyKind::MatchaPlus,
            "mst" => TopologyKind::Mst,
            "delta_mbst" | "dmbst" => TopologyKind::DeltaMbst,
            "ring" => TopologyKind::Ring,
            "multigraph" | "ours" => TopologyKind::Multigraph,
            other => bail!("unknown topology '{other}'"),
        })
    }
}

/// What isolated nodes do during training (DESIGN.md §7: the paper's
/// text supports both readings; `StaleAggregate` is the default used in
/// our experiments, `Skip` is the ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum IsolatedPolicy {
    /// Aggregate with the cached (k-h) stale neighbour models, without
    /// waiting (abstract: "model aggregation without waiting").
    #[default]
    StaleAggregate,
    /// Pure local update, no aggregation (§4.2: "update their weights
    /// internally and ignore all weakly-connected edges").
    Skip,
}

impl FromStr for IsolatedPolicy {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "stale_aggregate" | "stale" => IsolatedPolicy::StaleAggregate,
            "skip" | "local" => IsolatedPolicy::Skip,
            other => bail!("unknown isolated policy '{other}'"),
        })
    }
}

/// Which backend executes the Eq. 6 weighted model aggregation.
///
/// §Perf (EXPERIMENTS.md): on CPU-PJRT the compiled interpret-mode
/// kernel pays a ~73 MB zero-padded marshal plus XLA while-loop
/// overhead per call (~4.8 s at P=1.14M) while the native loop runs in
/// ~1.5 ms; `Native` is therefore the default. `Kernel` keeps the
/// TPU-shaped path exercised (used by tests and the hotpath bench, and
/// the right choice on a real accelerator where the stack stays
/// device-resident).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AggBackend {
    #[default]
    Native,
    Kernel,
}

impl std::str::FromStr for AggBackend {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "native" => AggBackend::Native,
            "kernel" | "pallas" => AggBackend::Kernel,
            other => bail!("unknown agg backend '{other}'"),
        })
    }
}

/// Training hyper-parameters for the real (PJRT-executed) runs.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Model name in artifacts/manifest.json.
    pub model: String,
    /// Communication rounds.
    pub rounds: usize,
    /// Local updates per round (paper: u = 1).
    pub local_updates: u32,
    pub lr: f32,
    /// Dirichlet alpha for the non-IID partition.
    pub dirichlet_alpha: f64,
    /// Per-silo synthetic training examples (bookkeeping).
    pub examples_per_silo: usize,
    pub eval_examples: usize,
    pub seed: u64,
    pub isolated_policy: IsolatedPolicy,
    pub agg_backend: AggBackend,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            model: "femnist_mlp".into(),
            rounds: 50,
            local_updates: 1,
            lr: 0.05,
            dirichlet_alpha: 0.5,
            examples_per_silo: 512,
            eval_examples: 512,
            seed: 17,
            isolated_policy: IsolatedPolicy::StaleAggregate,
            agg_backend: AggBackend::Native,
        }
    }
}

/// A full experiment: network x profile x topology (+ training).
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub network: String,
    /// Table 2 profile name: femnist | sentiment140 | inaturalist.
    pub profile: String,
    pub topology: TopologyKind,
    /// Maximum edges between two nodes (Algorithm 1's t; paper: 5).
    pub t: u32,
    /// Simulated communication rounds (paper: 6400).
    pub sim_rounds: usize,
    pub seed: u64,
    pub train: Option<TrainConfig>,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            network: "gaia".into(),
            profile: "femnist".into(),
            topology: TopologyKind::Multigraph,
            t: 5,
            sim_rounds: 6400,
            seed: 17,
            train: None,
        }
    }
}

impl ExperimentConfig {
    pub fn from_toml_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading config {}", path.as_ref().display()))?;
        let cfg = Self::from_toml_str(&text)?;
        cfg.validate()?;
        Ok(cfg)
    }

    /// Parse the TOML subset: comments (#), `[train]` section, flat
    /// `key = value` with string / number / bool values.
    pub fn from_toml_str(text: &str) -> Result<Self> {
        let mut cfg = ExperimentConfig::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                section = name.trim().to_string();
                if section == "train" && cfg.train.is_none() {
                    cfg.train = Some(TrainConfig::default());
                }
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = key.trim();
            let value = value.trim().trim_matches('"');
            let ctx = |k: &str| format!("line {}: key '{k}'", lineno + 1);
            match (section.as_str(), key) {
                ("", "network") => cfg.network = value.to_string(),
                ("", "profile") => cfg.profile = value.to_string(),
                ("", "topology") => cfg.topology = value.parse().with_context(|| ctx(key))?,
                ("", "t") => cfg.t = value.parse().with_context(|| ctx(key))?,
                ("", "sim_rounds") => cfg.sim_rounds = value.parse().with_context(|| ctx(key))?,
                ("", "seed") => cfg.seed = value.parse().with_context(|| ctx(key))?,
                ("train", k) => {
                    let t = cfg.train.as_mut().expect("section init");
                    match k {
                        "model" => t.model = value.to_string(),
                        "rounds" => t.rounds = value.parse().with_context(|| ctx(k))?,
                        "local_updates" => t.local_updates = value.parse().with_context(|| ctx(k))?,
                        "lr" => t.lr = value.parse().with_context(|| ctx(k))?,
                        "dirichlet_alpha" => {
                            t.dirichlet_alpha = value.parse().with_context(|| ctx(k))?
                        }
                        "examples_per_silo" => {
                            t.examples_per_silo = value.parse().with_context(|| ctx(k))?
                        }
                        "eval_examples" => t.eval_examples = value.parse().with_context(|| ctx(k))?,
                        "seed" => t.seed = value.parse().with_context(|| ctx(k))?,
                        "isolated_policy" => {
                            t.isolated_policy = value.parse().with_context(|| ctx(k))?
                        }
                        "agg_backend" => t.agg_backend = value.parse().with_context(|| ctx(k))?,
                        other => bail!("line {}: unknown [train] key '{other}'", lineno + 1),
                    }
                }
                (sec, other) => {
                    bail!("line {}: unknown key '{other}' in section '[{sec}]'", lineno + 1)
                }
            }
        }
        Ok(cfg)
    }

    /// Serialize back to the TOML subset (for example configs).
    pub fn to_toml_string(&self) -> String {
        let mut s = format!(
            "network = \"{}\"\nprofile = \"{}\"\ntopology = \"{}\"\nt = {}\nsim_rounds = {}\nseed = {}\n",
            self.network,
            self.profile,
            self.topology.as_str(),
            self.t,
            self.sim_rounds,
            self.seed
        );
        if let Some(t) = &self.train {
            s.push_str(&format!(
                "\n[train]\nmodel = \"{}\"\nrounds = {}\nlocal_updates = {}\nlr = {}\ndirichlet_alpha = {}\nexamples_per_silo = {}\neval_examples = {}\nseed = {}\nisolated_policy = \"{}\"\n",
                t.model,
                t.rounds,
                t.local_updates,
                t.lr,
                t.dirichlet_alpha,
                t.examples_per_silo,
                t.eval_examples,
                t.seed,
                match t.isolated_policy {
                    IsolatedPolicy::StaleAggregate => "stale_aggregate",
                    IsolatedPolicy::Skip => "skip",
                }
            ));
            s.push_str(&format!(
                "agg_backend = \"{}\"\n",
                match t.agg_backend {
                    AggBackend::Native => "native",
                    AggBackend::Kernel => "kernel",
                }
            ));
        }
        s
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(self.t >= 1, "t must be >= 1 (got {})", self.t);
        ensure!(self.sim_rounds >= 1, "sim_rounds must be >= 1");
        ensure!(
            crate::net::by_name(&self.network).is_some(),
            "unknown network '{}' (zoo name or synth-<variant>-n<N>-s<seed>)",
            self.network
        );
        self.resolve_profile()?;
        if let Some(t) = &self.train {
            ensure!(t.rounds >= 1, "train.rounds must be >= 1");
            ensure!(t.lr > 0.0, "train.lr must be positive");
            ensure!(t.local_updates >= 1, "train.local_updates must be >= 1");
            ensure!(t.dirichlet_alpha > 0.0, "train.dirichlet_alpha must be positive");
        }
        Ok(())
    }

    pub fn resolve_network(&self) -> crate::net::NetworkSpec {
        crate::net::by_name(&self.network).expect("validated")
    }

    pub fn resolve_profile(&self) -> Result<crate::net::DatasetProfile> {
        crate::net::DatasetProfile::by_name(&self.profile)
            .ok_or_else(|| anyhow::anyhow!("unknown profile '{}'", self.profile))
    }

    /// Build the configured topology design.
    pub fn build_topology(&self) -> Box<dyn crate::topo::TopologyDesign> {
        let net = self.resolve_network();
        let profile = self.resolve_profile().expect("validated");
        build_design(self.topology, &net, &profile, self.t, self.seed)
    }
}

/// The single kind → constructor dispatch (production/dense builders,
/// default budget and δ). [`ExperimentConfig::build_topology`], the
/// `mgfl scale` subcommand, and the scaling bench all build through
/// here, so they can never time or simulate a different construction
/// than sweeps actually run. Takes the network by reference — callers
/// with an in-hand (e.g. synthetic) network pay no name re-resolution.
pub fn build_design(
    kind: TopologyKind,
    net: &crate::net::NetworkSpec,
    profile: &crate::net::DatasetProfile,
    t: u32,
    seed: u64,
) -> Box<dyn crate::topo::TopologyDesign> {
    use crate::topo;
    match kind {
        TopologyKind::Star => Box::new(topo::star::StarTopology::new(net, profile)),
        TopologyKind::Matcha => Box::new(topo::matcha::MatchaTopology::new(
            net,
            profile,
            topo::matcha::DEFAULT_BUDGET,
            seed,
        )),
        TopologyKind::MatchaPlus => {
            Box::new(topo::matcha::MatchaTopology::plus(net, profile, seed))
        }
        TopologyKind::Mst => Box::new(topo::mst::MstTopology::new(net, profile)),
        TopologyKind::DeltaMbst => Box::new(topo::delta_mbst::DeltaMbstTopology::new(
            net,
            profile,
            topo::delta_mbst::DEFAULT_DELTA,
        )),
        TopologyKind::Ring => Box::new(topo::ring::RingTopology::new(net, profile)),
        TopologyKind::Multigraph => {
            Box::new(topo::MultigraphTopology::from_network(net, profile, t))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_validates() {
        ExperimentConfig::default().validate().unwrap();
    }

    #[test]
    fn rejects_bad_network_and_t() {
        let mut c = ExperimentConfig::default();
        c.network = "nowhere".into();
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.t = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn toml_roundtrip() {
        let cfg = ExperimentConfig {
            network: "exodus".into(),
            topology: TopologyKind::Ring,
            train: Some(TrainConfig { rounds: 7, lr: 0.125, ..Default::default() }),
            ..ExperimentConfig::default()
        };
        let text = cfg.to_toml_string();
        let back = ExperimentConfig::from_toml_str(&text).unwrap();
        assert_eq!(back.network, "exodus");
        assert_eq!(back.topology, TopologyKind::Ring);
        let t = back.train.unwrap();
        assert_eq!(t.rounds, 7);
        assert_eq!(t.lr, 0.125);
    }

    #[test]
    fn parses_comments_and_sections() {
        let text = r#"
# experiment
network = "gaia"   # inline comment
topology = "multigraph"
t = 3

[train]
model = "femnist_mlp"
rounds = 5
isolated_policy = "skip"
"#;
        let cfg = ExperimentConfig::from_toml_str(text).unwrap();
        assert_eq!(cfg.network, "gaia");
        assert_eq!(cfg.t, 3);
        let t = cfg.train.unwrap();
        assert_eq!(t.rounds, 5);
        assert_eq!(t.isolated_policy, IsolatedPolicy::Skip);
    }

    #[test]
    fn unknown_keys_are_errors() {
        assert!(ExperimentConfig::from_toml_str("bogus = 1").is_err());
        assert!(ExperimentConfig::from_toml_str("[train]\nbogus = 1").is_err());
    }

    #[test]
    fn topology_kind_parse_roundtrip() {
        for kind in TopologyKind::all() {
            assert_eq!(kind.as_str().parse::<TopologyKind>().unwrap(), kind);
        }
        assert!("bogus".parse::<TopologyKind>().is_err());
    }

    #[test]
    fn builds_every_topology_kind() {
        for kind in TopologyKind::all() {
            let cfg = ExperimentConfig {
                topology: kind,
                sim_rounds: 1,
                ..ExperimentConfig::default()
            };
            let topo = cfg.build_topology();
            assert_eq!(topo.name(), kind.as_str());
        }
    }

    #[test]
    fn kind_contracts_match_built_designs() {
        // The sweep scheduler trusts the kind-level determinism contract
        // before any design exists; it must agree with what the built
        // design itself reports, for every kind.
        for kind in TopologyKind::all() {
            let cfg = ExperimentConfig { topology: kind, ..ExperimentConfig::default() };
            let topo = cfg.build_topology();
            assert_eq!(
                topo.seed_sensitive(),
                kind.seed_sensitive(),
                "kind/design seed_sensitive mismatch for {kind:?}"
            );
            if kind.seed_sensitive() {
                assert!(topo.period().is_none(), "{kind:?}: stochastic designs have no period");
            }
            // The compile cache collapses the t axis for !t_sensitive
            // kinds, so a wrong `false` would silently serve one t's
            // schedule for every t: require plan equality across t.
            if !kind.t_sensitive() {
                let build = |t: u32| {
                    ExperimentConfig { topology: kind, t, ..ExperimentConfig::default() }
                        .build_topology()
                };
                let (mut a, mut b) = (build(3), build(7));
                for k in 0..4 {
                    assert_eq!(
                        a.plan(k).edges,
                        b.plan(k).edges,
                        "{kind:?} claims t-insensitivity but t changes its round-{k} plan"
                    );
                }
            }
        }
        assert!(TopologyKind::Multigraph.t_sensitive());
        assert!(!TopologyKind::Ring.t_sensitive());
    }

    #[test]
    fn from_toml_file_errors_on_missing() {
        assert!(ExperimentConfig::from_toml_file("/nonexistent.toml").is_err());
    }
}
