//! Optimize specifications: the knobs of one `mgfl optimize` run as a
//! typed value with the same TOML-subset loader dialect as
//! [`crate::sweep::SweepSpec`] (comments, flat `key = value`, `[list]`
//! values), plus canonicalize/validate so committed specs are
//! coordinate-stable. See `rust/docs/SPECS.md` for the key-by-key
//! reference.

use std::path::Path;
use std::str::FromStr;

use anyhow::{bail, ensure, Context, Result};

use crate::net::DatasetProfile;
use crate::sweep::spec::{one, split_values};

/// Which [`crate::search::SearchStrategy`] drives the chains.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StrategyKind {
    /// Greedy hill-climbing with random restarts after a stall.
    Hill,
    /// Simulated annealing (Metropolis acceptance, geometric cooling).
    Anneal,
}

impl StrategyKind {
    /// Spec/report spelling (`"hill"` / `"anneal"`).
    pub fn as_str(&self) -> &'static str {
        match self {
            StrategyKind::Hill => "hill",
            StrategyKind::Anneal => "anneal",
        }
    }
}

impl FromStr for StrategyKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "hill" => Ok(StrategyKind::Hill),
            "anneal" => Ok(StrategyKind::Anneal),
            other => bail!("unknown search strategy '{other}' (hill | anneal)"),
        }
    }
}

/// One topology-search run: network, fitness budget, move-space bounds,
/// and strategy knobs. The whole search is a pure function of this
/// value — every RNG stream derives from `seed` and a chain label, so
/// the [`crate::metrics::search::SearchReport`] is byte-identical on
/// any thread count.
#[derive(Debug, Clone)]
pub struct OptimizeSpec {
    /// Artifact stem (`optimize_<name>.json` / `.csv`).
    pub name: String,
    /// Network to optimize over (zoo name or `synth-<variant>-n<N>-s<seed>`).
    pub network: String,
    /// Dataset profile supplying model size and local-computation time.
    pub profile: String,
    /// Simulated rounds per fitness evaluation.
    pub rounds: usize,
    /// Base seed; chain and init streams derive from it by label.
    pub seed: u64,
    /// Independent search chains (chain 0 starts from the paper design).
    pub chains: usize,
    /// Proposal steps per chain.
    pub steps: usize,
    /// Hill-climbing only: consecutive rejected proposals before a
    /// random restart.
    pub restart_after: usize,
    /// Chain driver: hill-climbing or simulated annealing.
    pub strategy: StrategyKind,
    /// Smallest Algorithm-1 `t` the search may pick.
    pub t_min: u32,
    /// Largest Algorithm-1 `t` the search may pick.
    pub t_max: u32,
    /// `t` of the paper-multigraph baseline (and chain 0's start,
    /// clamped into `[t_min, t_max]`).
    pub baseline_t: u32,
    /// Overlay degree cap; 2 disables chord moves (pure ring search).
    pub max_degree: usize,
    /// Annealing start temperature (ms of fitness, Metropolis scale).
    pub anneal_t0: f64,
    /// Annealing geometric cooling factor per step, in (0, 1).
    pub anneal_alpha: f64,
    /// MATCHA communication budgets to probe alongside the search
    /// (reported for comparison; never a search winner).
    pub matcha_budgets: Vec<f64>,
    /// Wall-clock budget for the whole run, ms; 0 disables. When the
    /// deadline passes, chains stop proposing at their next step and
    /// finish gracefully with the best genome found so far, and the
    /// report records `budget_exhausted = true`. **A firing deadline
    /// makes which step stops host-dependent**, so the trimmed trace —
    /// unlike every other artifact field — is not reproducible across
    /// machines; committed specs keep 0.
    pub deadline_ms: u64,
}

impl Default for OptimizeSpec {
    fn default() -> Self {
        OptimizeSpec {
            name: "optimize".into(),
            network: "gaia".into(),
            profile: "femnist".into(),
            rounds: 600,
            seed: 17,
            chains: 4,
            steps: 400,
            restart_after: 80,
            strategy: StrategyKind::Hill,
            t_min: 3,
            t_max: 10,
            baseline_t: 5,
            max_degree: 3,
            anneal_t0: 2.0,
            anneal_alpha: 0.995,
            matcha_budgets: Vec::new(),
            deadline_ms: 0,
        }
    }
}

impl OptimizeSpec {
    /// Rewrite network/profile names to their canonical spelling (same
    /// contract as [`crate::sweep::SweepSpec::canonicalize`]): the names
    /// feed RNG stream labels, so equivalent spellings must derive
    /// identical streams. Errors on unknown names.
    pub fn canonicalize(&mut self) -> Result<()> {
        self.network = crate::net::by_name(&self.network)
            .ok_or_else(|| anyhow::anyhow!("unknown network '{}'", self.network))?
            .name;
        self.profile = DatasetProfile::by_name(&self.profile)
            .ok_or_else(|| anyhow::anyhow!("unknown profile '{}'", self.profile))?
            .name;
        Ok(())
    }

    /// Check every knob is in-range and the network is searchable.
    pub fn validate(&self) -> Result<()> {
        ensure!(!self.name.is_empty(), "optimize name must be non-empty");
        ensure!(self.rounds >= 1, "rounds must be >= 1");
        ensure!(
            self.seed < (1u64 << 53),
            "seed {} exceeds 2^53 and would lose precision in JSON artifacts",
            self.seed
        );
        ensure!(self.chains >= 1, "chains must be >= 1");
        ensure!(self.steps >= 1, "steps must be >= 1");
        ensure!(self.restart_after >= 1, "restart_after must be >= 1");
        ensure!(self.t_min >= 1, "t_min must be >= 1 (got {})", self.t_min);
        ensure!(
            self.t_min <= self.t_max,
            "t_min {} must be <= t_max {}",
            self.t_min,
            self.t_max
        );
        ensure!(self.baseline_t >= 1, "baseline_t must be >= 1");
        ensure!(
            self.max_degree >= 2,
            "max_degree must be >= 2 (a ring already has degree 2)"
        );
        ensure!(
            self.anneal_t0.is_finite() && self.anneal_t0 > 0.0,
            "anneal_t0 must be positive and finite (got {})",
            self.anneal_t0
        );
        ensure!(
            self.anneal_alpha > 0.0 && self.anneal_alpha < 1.0,
            "anneal_alpha must be in (0, 1) (got {})",
            self.anneal_alpha
        );
        for &b in &self.matcha_budgets {
            ensure!(
                b.is_finite() && b > 0.0 && b <= 1.0,
                "matcha budget {b} must be in (0, 1]"
            );
        }
        for (i, b) in self.matcha_budgets.iter().enumerate() {
            ensure!(
                !self.matcha_budgets[..i].contains(b),
                "matcha_budgets lists {b} twice"
            );
        }
        let net = crate::net::by_name(&self.network).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown network '{}' (zoo name or synth-<variant>-n<N>-s<seed>)",
                self.network
            )
        })?;
        ensure!(
            net.n() >= 3,
            "network '{}' has {} silos; the overlay move set needs >= 3",
            self.network,
            net.n()
        );
        ensure!(
            DatasetProfile::by_name(&self.profile).is_some(),
            "unknown profile '{}'",
            self.profile
        );
        Ok(())
    }

    /// Load, canonicalize, and validate a spec file.
    pub fn from_toml_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading optimize spec {}", path.as_ref().display()))?;
        let mut spec = Self::from_toml_str(&text)?;
        spec.canonicalize()?;
        spec.validate()?;
        Ok(spec)
    }

    /// Parse the TOML subset (comments, flat `key = value`, `[list]`
    /// values); unknown keys error with their line number.
    pub fn from_toml_str(text: &str) -> Result<Self> {
        let mut spec = OptimizeSpec::default();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                bail!("line {}: optimize specs have no sections (got '{line}')", lineno + 1);
            }
            let (key, value) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = key.trim();
            let items = split_values(value);
            let ctx = |k: &str| format!("line {}: key '{k}'", lineno + 1);
            match key {
                "name" => spec.name = one(&items, key, lineno)?,
                "network" => spec.network = one(&items, key, lineno)?,
                "profile" => spec.profile = one(&items, key, lineno)?,
                "rounds" => {
                    spec.rounds = one(&items, key, lineno)?.parse().with_context(|| ctx(key))?
                }
                "seed" => {
                    spec.seed = one(&items, key, lineno)?.parse().with_context(|| ctx(key))?
                }
                "chains" => {
                    spec.chains = one(&items, key, lineno)?.parse().with_context(|| ctx(key))?
                }
                "steps" => {
                    spec.steps = one(&items, key, lineno)?.parse().with_context(|| ctx(key))?
                }
                "restart_after" => {
                    spec.restart_after =
                        one(&items, key, lineno)?.parse().with_context(|| ctx(key))?
                }
                "strategy" => {
                    spec.strategy = one(&items, key, lineno)?.parse().with_context(|| ctx(key))?
                }
                "t_min" => {
                    spec.t_min = one(&items, key, lineno)?.parse().with_context(|| ctx(key))?
                }
                "t_max" => {
                    spec.t_max = one(&items, key, lineno)?.parse().with_context(|| ctx(key))?
                }
                "baseline_t" => {
                    spec.baseline_t =
                        one(&items, key, lineno)?.parse().with_context(|| ctx(key))?
                }
                "max_degree" => {
                    spec.max_degree =
                        one(&items, key, lineno)?.parse().with_context(|| ctx(key))?
                }
                "anneal_t0" => {
                    spec.anneal_t0 = one(&items, key, lineno)?.parse().with_context(|| ctx(key))?
                }
                "anneal_alpha" => {
                    spec.anneal_alpha =
                        one(&items, key, lineno)?.parse().with_context(|| ctx(key))?
                }
                "matcha_budgets" => {
                    spec.matcha_budgets = items
                        .iter()
                        .map(|s| s.parse::<f64>())
                        .collect::<Result<_, _>>()
                        .with_context(|| ctx(key))?
                }
                "deadline_ms" => {
                    spec.deadline_ms =
                        one(&items, key, lineno)?.parse().with_context(|| ctx(key))?
                }
                other => bail!("line {}: unknown optimize key '{other}'", lineno + 1),
            }
        }
        Ok(spec)
    }

    /// Serialize back to the TOML subset (for shipped example specs).
    pub fn to_toml_string(&self) -> String {
        let budgets: Vec<String> = self.matcha_budgets.iter().map(|b| b.to_string()).collect();
        format!(
            "name = \"{}\"\nnetwork = \"{}\"\nprofile = \"{}\"\nrounds = {}\nseed = {}\n\
             strategy = \"{}\"\nchains = {}\nsteps = {}\nrestart_after = {}\n\
             t_min = {}\nt_max = {}\nbaseline_t = {}\nmax_degree = {}\n\
             anneal_t0 = {}\nanneal_alpha = {}\nmatcha_budgets = [{}]\ndeadline_ms = {}\n",
            self.name,
            self.network,
            self.profile,
            self.rounds,
            self.seed,
            self.strategy.as_str(),
            self.chains,
            self.steps,
            self.restart_after,
            self.t_min,
            self.t_max,
            self.baseline_t,
            self.max_degree,
            self.anneal_t0,
            self.anneal_alpha,
            budgets.join(", "),
            self.deadline_ms,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_spec_validates() {
        let mut spec = OptimizeSpec::default();
        spec.canonicalize().unwrap();
        spec.validate().unwrap();
        assert_eq!(spec.strategy, StrategyKind::Hill);
    }

    #[test]
    fn toml_roundtrip() {
        let spec = OptimizeSpec {
            name: "probe".into(),
            network: "exodus".into(),
            strategy: StrategyKind::Anneal,
            t_min: 2,
            t_max: 7,
            matcha_budgets: vec![0.3, 0.7],
            deadline_ms: 1500,
            ..Default::default()
        };
        let back = OptimizeSpec::from_toml_str(&spec.to_toml_string()).unwrap();
        assert_eq!(back.name, "probe");
        assert_eq!(back.network, "exodus");
        assert_eq!(back.strategy, StrategyKind::Anneal);
        assert_eq!(back.t_min, 2);
        assert_eq!(back.t_max, 7);
        assert_eq!(back.matcha_budgets, vec![0.3, 0.7]);
        assert_eq!(back.anneal_alpha, spec.anneal_alpha);
        assert_eq!(back.deadline_ms, 1500);
    }

    #[test]
    fn parses_comments_and_rejects_unknown_keys() {
        let text = "# search gaia\nname = \"g\"  # stem\nsteps = 40\nstrategy = anneal\n";
        let spec = OptimizeSpec::from_toml_str(text).unwrap();
        assert_eq!(spec.name, "g");
        assert_eq!(spec.steps, 40);
        assert_eq!(spec.strategy, StrategyKind::Anneal);
        assert!(OptimizeSpec::from_toml_str("bogus = 1").is_err());
        assert!(OptimizeSpec::from_toml_str("[section]").is_err());
        assert!(OptimizeSpec::from_toml_str("strategy = tabu").is_err());
    }

    #[test]
    fn rejects_bad_specs() {
        let bad = |f: fn(&mut OptimizeSpec)| {
            let mut s = OptimizeSpec::default();
            f(&mut s);
            s.validate().is_err()
        };
        assert!(bad(|s| s.chains = 0));
        assert!(bad(|s| s.steps = 0));
        assert!(bad(|s| s.rounds = 0));
        assert!(bad(|s| s.t_min = 0));
        assert!(bad(|s| { s.t_min = 6; s.t_max = 5 }));
        assert!(bad(|s| s.max_degree = 1));
        assert!(bad(|s| s.anneal_alpha = 1.0));
        assert!(bad(|s| s.anneal_t0 = 0.0));
        assert!(bad(|s| s.seed = 1u64 << 53));
        assert!(bad(|s| s.matcha_budgets = vec![1.5]));
        assert!(bad(|s| s.matcha_budgets = vec![0.5, 0.5]));
        assert!(bad(|s| s.network = "nowhere".into()));
        assert!(OptimizeSpec::from_toml_file("/nonexistent.toml").is_err());
    }

    #[test]
    fn canonicalize_is_case_stable() {
        let mut spec = OptimizeSpec {
            network: "GAIA".into(),
            profile: "FEMNIST".into(),
            ..Default::default()
        };
        spec.canonicalize().unwrap();
        assert_eq!(spec.network, "gaia");
        assert_eq!(spec.profile, "femnist");
    }
}
