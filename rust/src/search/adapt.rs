//! Self-healing topologies: online re-optimization at `Timeline`
//! segment boundaries.
//!
//! The paper's multigraph schedule is fixed at construction time, so a
//! single silo departure degrades every remaining round — PR 9 models
//! that honestly (the masked static topology limps through the churn),
//! and this module closes the loop: at every segment boundary whose
//! up-mask changed, re-plan the overlay on the *surviving* network and
//! splice the new schedule into the running simulation.
//!
//! # Policies
//!
//! * [`AdaptPolicy::None`] — no adaptation; the planner reproduces the
//!   PR 9 piecewise-static walk bitwise (the control row of every
//!   adaptive sweep).
//! * [`AdaptPolicy::Rebuild`] — re-run the paper's own pipeline on the
//!   survivors: Christofides ring over the surviving sub-connectivity,
//!   then Algorithms 1–2 via [`CandidateTopology`].
//! * [`AdaptPolicy::Warm`] — hill-climb from the previous segment's
//!   genome (survivors keep their ring order, rejoined silos are
//!   appended, dead chords dropped) under a per-boundary evaluation
//!   budget and optional wall-clock deadline; fitness is the mean τ of
//!   a short masked-tracker run on the surviving network.
//!
//! # Reconfiguration cost
//!
//! Adaptation is never free: each re-planned boundary first *freezes*
//! on the outgoing topology (under the new mask) for
//! `freeze_rounds` — modeling overlay deployment — before the new
//! schedule activates at offset 0.
//!
//! # Graceful degradation
//!
//! The fallback ladder never fails a cell: warm search out of budget
//! or past its deadline falls to the rebuilt paper design; a rebuild
//! that cannot produce a valid overlay (or a segment network too small
//! to plan on) falls to the PR 9 masked static base. Every step down
//! is counted in [`AdaptMetrics::fallbacks`].
//!
//! # Determinism
//!
//! Search RNG streams derive from the **scenario** seed and structural
//! labels (`adapt/<policy>/seg/<i>`), never from wall-clock or thread
//! identity, so adaptive artifacts are byte-identical across threads,
//! dedup modes, and store warmth. The one deliberate exception is
//! `deadline_ms > 0`: a firing wall-clock deadline makes the accepted
//! step count host-dependent, so committed specs keep it at 0 and
//! exercise the fallback ladder through zero budgets instead.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use crate::delay::{pair_d0_ms, EdgeType};
use crate::graph::{christofides_cycle_dense, DenseGraph, Graph};
use crate::net::{DatasetProfile, NetworkSpec};
use crate::simtime::scenario::finalize;
use crate::simtime::{
    build_timeline, run_spliced, AdaptMetrics, EngineKind, EngineStats, ScenarioSpec, SimSummary,
    SplicedPhase, Timeline,
};
use crate::topo::{CandidateTopology, MaskedTopology, TopologyDesign};
use crate::util::rng::{fnv1a, named_stream};
use crate::util::Rng64;

/// Overlay degree cap for warm-search chord moves (ring contributes 2,
/// chords the rest) — mirrors `mgfl optimize`'s default `max_degree`.
const ADAPT_MAX_DEGREE: usize = 3;

/// Proposal attempts allowed per budgeted evaluation before a warm
/// search gives up on finding valid moves (tiny surviving networks can
/// reject every reorder).
const ATTEMPTS_PER_EVAL: usize = 8;

/// What to do at a segment boundary whose up-mask changed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdaptPolicy {
    /// Keep the static base topology (PR 9 behavior, bit-for-bit).
    None,
    /// Re-run the paper pipeline (Christofides ring → Algorithms 1–2)
    /// over the surviving silos.
    Rebuild,
    /// Warm-started hill climb from the previous segment's genome,
    /// bounded by [`AdaptSpec::budget`] and [`AdaptSpec::deadline_ms`].
    Warm,
}

impl AdaptPolicy {
    /// The spec-file token for this policy.
    pub fn as_str(&self) -> &'static str {
        match self {
            AdaptPolicy::None => "none",
            AdaptPolicy::Rebuild => "rebuild",
            AdaptPolicy::Warm => "warm",
        }
    }

    /// Parse a spec-file token (`none` | `rebuild` | `warm`).
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "none" => Ok(AdaptPolicy::None),
            "rebuild" => Ok(AdaptPolicy::Rebuild),
            "warm" => Ok(AdaptPolicy::Warm),
            other => anyhow::bail!("unknown adapt policy '{other}' (none|rebuild|warm)"),
        }
    }

    /// Whether this policy ever re-plans (everything except `none`).
    pub fn is_active(&self) -> bool {
        !matches!(self, AdaptPolicy::None)
    }
}

/// One cell's resolved adaptation configuration: the policy plus the
/// shared knobs of the `[adapt]` sweep section.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AdaptSpec {
    /// Boundary policy.
    pub policy: AdaptPolicy,
    /// Fitness evaluations allowed per re-planned boundary (`warm`
    /// only; evaluating the warm start costs 1). A zero budget cannot
    /// evaluate anything and falls back to `rebuild` at every
    /// boundary — the deterministic way to exercise the ladder.
    pub budget: usize,
    /// Wall-clock deadline per boundary, ms; 0 disables. **A firing
    /// deadline makes results host-dependent** — committed specs keep 0.
    pub deadline_ms: u64,
    /// Rounds frozen on the outgoing topology while a new overlay
    /// "deploys" (clamped to the segment length).
    pub freeze_rounds: usize,
    /// Rounds of the masked-tracker fitness probe per candidate.
    pub eval_rounds: usize,
}

impl Default for AdaptSpec {
    fn default() -> Self {
        AdaptSpec {
            policy: AdaptPolicy::None,
            budget: 48,
            deadline_ms: 0,
            freeze_rounds: 4,
            eval_rounds: 80,
        }
    }
}

impl AdaptSpec {
    /// Canonical serialization — the store-key/fingerprint preimage.
    pub fn canonical_string(&self) -> String {
        format!(
            "policy={};budget={};deadline_ms={};freeze={};eval={}",
            self.policy.as_str(),
            self.budget,
            self.deadline_ms,
            self.freeze_rounds,
            self.eval_rounds
        )
    }

    /// FNV-1a fingerprint of [`Self::canonical_string`]. Joins
    /// [`crate::sweep::CellFingerprint`] and the store cell key for
    /// active policies, so adaptive cells never cross-hit static ones.
    pub fn fingerprint(&self) -> u64 {
        fnv1a(self.canonical_string().as_bytes())
    }

    /// Whether this spec re-plans at boundaries (policy ≠ `none`).
    pub fn is_active(&self) -> bool {
        self.policy.is_active()
    }

    /// Range checks for spec-file input.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.eval_rounds >= 1, "[adapt] eval_rounds must be >= 1");
        Ok(())
    }
}

/// The warm-search genome over one segment's survivors: a ring of
/// *global* up-silo ids plus chord pairs (global, `u < v`, both up).
/// `t` is not searched — the cell's own `t` carries over, keeping the
/// per-boundary budget spent on the overlay.
#[derive(Debug, Clone, PartialEq, Eq)]
struct AdaptGenome {
    order: Vec<usize>,
    chords: Vec<(usize, usize)>,
}

impl AdaptGenome {
    /// Whether normalized `(u, v)` is a ring edge of `order`.
    fn has_ring_pair(&self, u: usize, v: usize) -> bool {
        let k = self.order.len();
        (0..k).any(|i| {
            let (a, b) = (self.order[i], self.order[(i + 1) % k]);
            (a.min(b), a.max(b)) == (u, v)
        })
    }

    /// Overlay degree of every *up* silo (ring 2 each, chords 1 per
    /// endpoint), keyed by global id.
    fn degrees(&self, n: usize) -> Vec<usize> {
        let mut deg = vec![0usize; n];
        let k = self.order.len();
        for i in 0..k {
            deg[self.order[i]] += 1;
            deg[self.order[(i + 1) % k]] += 1;
        }
        for &(u, v) in &self.chords {
            deg[u] += 1;
            deg[v] += 1;
        }
        deg
    }
}

/// The paper-design genome over the survivors: Christofides ring on the
/// surviving sub-connectivity (the `remove_silos` idiom), no chords.
fn rebuild_genome(net: &NetworkSpec, profile: &DatasetProfile, up_ids: &[usize]) -> AdaptGenome {
    let conn = net.connectivity_dense(profile);
    let sub = DenseGraph::from_fn(up_ids.len(), |a, b| conn.weight(up_ids[a], up_ids[b]));
    let cycle = christofides_cycle_dense(&sub);
    AdaptGenome { order: cycle.into_iter().map(|i| up_ids[i]).collect(), chords: Vec::new() }
}

/// Project the previous segment's genome onto a new up-set: survivors
/// keep their relative ring order, rejoined silos append in index
/// order, chords keep only up-up pairs that are not ring edges of the
/// projected ring.
fn project_genome(prev: &AdaptGenome, up: &[bool], up_ids: &[usize]) -> AdaptGenome {
    let mut order: Vec<usize> = prev.order.iter().copied().filter(|&s| up[s]).collect();
    for &s in up_ids {
        if !order.contains(&s) {
            order.push(s);
        }
    }
    let mut g = AdaptGenome { order, chords: Vec::new() };
    let mut chords: Vec<(usize, usize)> = prev
        .chords
        .iter()
        .copied()
        .filter(|&(u, v)| up[u] && up[v] && !g.has_ring_pair(u, v))
        .collect();
    chords.sort_unstable();
    chords.dedup();
    g.chords = chords;
    g
}

/// Materialize a genome into a full-`n` connected overlay: ring edges
/// over consecutive order pairs (a 2-silo ring is a single edge),
/// chords, and every *down* silo attached to its cheapest up anchor
/// (min Eq. 3 weight, ties to the lowest index) so
/// [`CandidateTopology`] can run the paper pipeline over the whole
/// network. The anchor edges are masked out at run time — they exist
/// only so Algorithms 1–2 see a connected overlay.
fn materialize_overlay(
    g: &AdaptGenome,
    net: &NetworkSpec,
    profile: &DatasetProfile,
    up: &[bool],
) -> Graph {
    let mut ov = Graph::new(net.n());
    let k = g.order.len();
    for i in 0..k {
        if k == 2 && i == 1 {
            break; // 2-node ring is a single edge, not a double edge
        }
        let (a, b) = (g.order[i], g.order[(i + 1) % k]);
        ov.add_edge(a, b, net.conn_weight(profile, a, b));
    }
    for &(u, v) in &g.chords {
        ov.add_edge(u, v, net.conn_weight(profile, u, v));
    }
    for d in 0..net.n() {
        if up[d] {
            continue;
        }
        let anchor = g
            .order
            .iter()
            .copied()
            .map(|u| (net.conn_weight(profile, d, u), u))
            .min_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)))
            .expect("planned segments have at least 2 up silos");
        ov.add_edge(d, anchor.1, anchor.0);
    }
    ov
}

/// Mean τ of a short masked single-phase tracker run over the
/// survivors — the warm search's fitness. Runs at scale 1.0 (capacity
/// shifts rescale candidates near-uniformly, so they cannot change the
/// ranking enough to buy their cost here).
fn eval_genome(
    g: &AdaptGenome,
    net: &NetworkSpec,
    profile: &DatasetProfile,
    t: u32,
    up: &[bool],
    eval_rounds: usize,
) -> f64 {
    let ov = materialize_overlay(g, net, profile, up);
    let mut topos: Vec<Box<dyn TopologyDesign>> =
        vec![Box::new(CandidateTopology::new(ov, net, profile, t))];
    let phase =
        SplicedPhase { topo: 0, offset: 0, up: up.to_vec(), scale: 1.0, len: eval_rounds };
    let (tau, _) = run_spliced(&mut topos, std::slice::from_ref(&phase), net, profile);
    tau.iter().sum::<f64>() / tau.len() as f64
}

/// Propose one mutation: `two_opt` / `or_opt` ring reorders (invalid on
/// rings too small to reorder), `chord_add` under the degree cap,
/// `chord_drop`. Returns `None` for invalid draws — the search treats
/// that as a skipped attempt. Draw counts per arm are fixed, so the
/// stream stays deterministic.
fn propose_adapt(g: &AdaptGenome, rng: &mut Rng64, n: usize) -> Option<AdaptGenome> {
    let k = g.order.len();
    let kinds = ["two_opt", "or_opt", "chord_add", "chord_drop"];
    let kind = kinds[rng.gen_range(0, kinds.len())];
    let mut out = g.clone();
    match kind {
        "two_opt" => {
            if k < 4 {
                return None;
            }
            let i = rng.gen_range(1, k - 1);
            let j = rng.gen_range(i + 1, k);
            out.order[i..=j].reverse();
        }
        "or_opt" => {
            if k < 3 {
                return None;
            }
            let i = rng.gen_range(1, k);
            let j = rng.gen_range(1, k);
            let node = out.order.remove(i);
            let pos = j.min(out.order.len());
            out.order.insert(pos, node);
        }
        "chord_add" => {
            if k < 4 {
                return None; // every pair of a <4-ring is a ring edge
            }
            let a = rng.gen_range(0, k);
            let b = rng.gen_range(0, k);
            let (u, v) = (g.order[a], g.order[b]);
            if u == v {
                return None;
            }
            let (u, v) = (u.min(v), u.max(v));
            if out.has_ring_pair(u, v) || out.chords.contains(&(u, v)) {
                return None;
            }
            let deg = out.degrees(n);
            if deg[u] >= ADAPT_MAX_DEGREE || deg[v] >= ADAPT_MAX_DEGREE {
                return None;
            }
            out.chords.push((u, v));
            out.chords.sort_unstable();
        }
        "chord_drop" => {
            if out.chords.is_empty() {
                return None;
            }
            let i = rng.gen_range(0, out.chords.len());
            out.chords.remove(i);
        }
        _ => unreachable!("kind drawn from the kinds list"),
    }
    Some(out)
}

/// Warm-started greedy hill climb over one boundary's survivors.
/// Returns `None` when the budget is zero or the deadline fires before
/// the warm start itself is evaluated — the caller falls back to
/// rebuild. RNG stream: `adapt/<policy>/seg/<segment index>` off the
/// *scenario* seed, so deterministic-topology adaptive cells stay
/// identical across the sweep's seed axis.
#[allow(clippy::too_many_arguments)]
fn warm_search(
    net: &NetworkSpec,
    profile: &DatasetProfile,
    t: u32,
    up: &[bool],
    up_ids: &[usize],
    seg_idx: usize,
    sc_seed: u64,
    spec: &AdaptSpec,
    prev: Option<&AdaptGenome>,
    rebuild: AdaptGenome,
    metrics: &mut AdaptMetrics,
) -> Option<AdaptGenome> {
    if spec.budget == 0 {
        return None;
    }
    let deadline = (spec.deadline_ms > 0)
        .then(|| Instant::now() + Duration::from_millis(spec.deadline_ms));
    let past_deadline = |d: &Option<Instant>| d.map_or(false, |d| Instant::now() >= d);
    if past_deadline(&deadline) {
        return None;
    }
    let label = format!("adapt/{}/seg/{}", spec.policy.as_str(), seg_idx);
    let mut rng = Rng64::seed_from_u64(named_stream(sc_seed, &label));
    let start = match prev {
        Some(p) => project_genome(p, up, up_ids),
        None => rebuild,
    };
    let mut best_fit = eval_genome(&start, net, profile, t, up, spec.eval_rounds);
    let mut best = start;
    let mut evals = 1usize;
    let max_attempts = spec.budget.saturating_mul(ATTEMPTS_PER_EVAL);
    let mut attempts = 0usize;
    while evals < spec.budget && attempts < max_attempts && !past_deadline(&deadline) {
        attempts += 1;
        if let Some(cand) = propose_adapt(&best, &mut rng, net.n()) {
            let fit = eval_genome(&cand, net, profile, t, up, spec.eval_rounds);
            evals += 1;
            if fit < best_fit {
                best_fit = fit;
                best = cand;
            }
        }
    }
    metrics.evals_spent += evals;
    Some(best)
}

/// Plan one boundary's replacement topology, walking the fallback
/// ladder (warm → rebuild → `None` = masked static base). Every step
/// down increments `metrics.fallbacks`.
#[allow(clippy::too_many_arguments)]
fn plan_segment_topology(
    net: &NetworkSpec,
    profile: &DatasetProfile,
    t: u32,
    up: &[bool],
    seg_idx: usize,
    sc_seed: u64,
    spec: &AdaptSpec,
    prev: Option<&AdaptGenome>,
    metrics: &mut AdaptMetrics,
) -> Option<(Box<dyn TopologyDesign>, AdaptGenome)> {
    let up_ids: Vec<usize> =
        up.iter().enumerate().filter(|&(_, &u)| u).map(|(i, _)| i).collect();
    if up_ids.len() < 2 {
        // Invalid segment network: nothing to plan on.
        metrics.fallbacks += 1;
        return None;
    }
    let rebuild = rebuild_genome(net, profile, &up_ids);
    let genome = if spec.policy == AdaptPolicy::Warm {
        match warm_search(
            net, profile, t, up, &up_ids, seg_idx, sc_seed, spec, prev, rebuild.clone(), metrics,
        ) {
            Some(g) => g,
            None => {
                metrics.fallbacks += 1;
                rebuild
            }
        }
    } else {
        rebuild
    };
    let overlay = materialize_overlay(&genome, net, profile, up);
    if !overlay.is_connected() {
        // Structurally invalid rebuild: fall to the masked static base.
        metrics.fallbacks += 1;
        return None;
    }
    Some((Box::new(CandidateTopology::new(overlay, net, profile, t)), genome))
}

/// A fully planned adaptive run: the topology table, the spliced phase
/// sequence covering `0..rounds`, and the accounting.
struct Planned {
    topos: Vec<Box<dyn TopologyDesign>>,
    phases: Vec<SplicedPhase>,
    metrics: AdaptMetrics,
}

/// The deterministic adaptation planner. Segment 0 always runs the
/// static base at PR 9's global offset; later boundaries whose mask is
/// unchanged continue the current topology; changed masks under an
/// active policy freeze, re-plan, and splice. Shared verbatim by the
/// engine and the oracle, so both step identical phases.
fn plan_adaptation(
    base: Box<dyn TopologyDesign>,
    net: &NetworkSpec,
    profile: &DatasetProfile,
    t: u32,
    tl: &Timeline,
    sc_seed: u64,
    spec: &AdaptSpec,
) -> Planned {
    let mut topos: Vec<Box<dyn TopologyDesign>> = vec![base];
    let mut phases: Vec<SplicedPhase> = Vec::new();
    let mut metrics = AdaptMetrics {
        policy: spec.policy.as_str().to_string(),
        replans: 0,
        fallbacks: 0,
        evals_spent: 0,
        freeze_rounds: 0,
    };
    // Current topology: table index plus activation round (`None` =
    // the base, which keeps PR 9's global-round schedule offset).
    let mut cur = 0usize;
    let mut cur_origin: Option<usize> = None;
    let mut cur_genome: Option<AdaptGenome> = None;
    let offset_for = |origin: Option<usize>, start: usize| match origin {
        None => start,
        Some(g0) => start - g0,
    };
    for (i, seg) in tl.segments.iter().enumerate() {
        let mask_changed = i > 0 && seg.up != tl.segments[i - 1].up;
        if !spec.policy.is_active() || !mask_changed {
            phases.push(SplicedPhase {
                topo: cur,
                offset: offset_for(cur_origin, seg.start),
                up: seg.up.clone(),
                scale: seg.scale,
                len: seg.len,
            });
            continue;
        }
        let freeze = spec.freeze_rounds.min(seg.len);
        if freeze > 0 {
            phases.push(SplicedPhase {
                topo: cur,
                offset: offset_for(cur_origin, seg.start),
                up: seg.up.clone(),
                scale: seg.scale,
                len: freeze,
            });
            metrics.freeze_rounds += freeze;
        }
        match plan_segment_topology(
            net,
            profile,
            t,
            &seg.up,
            i,
            sc_seed,
            spec,
            cur_genome.as_ref(),
            &mut metrics,
        ) {
            Some((topo, genome)) => {
                topos.push(topo);
                cur = topos.len() - 1;
                cur_origin = Some(seg.start + freeze);
                cur_genome = Some(genome);
                metrics.replans += 1;
            }
            None => {
                cur = 0;
                cur_origin = None;
                cur_genome = None;
            }
        }
        if seg.len > freeze {
            phases.push(SplicedPhase {
                topo: cur,
                offset: offset_for(cur_origin, seg.start + freeze),
                up: seg.up.clone(),
                scale: seg.scale,
                len: seg.len - freeze,
            });
        }
    }
    Planned { topos, phases, metrics }
}

/// The adaptive scenario engine: plan, splice, step, finalize. The
/// summary's topology name is the *base* design's (the policy column
/// distinguishes adaptive rows); engine kind is always `Streaming`
/// (spliced schedules are aperiodic by construction). With
/// `policy = "none"` this is bitwise the PR 9 masked tracker.
pub fn simulate_summary_adaptive(
    base: Box<dyn TopologyDesign>,
    net: &NetworkSpec,
    profile: &DatasetProfile,
    rounds: usize,
    sc: &ScenarioSpec,
    spec: &AdaptSpec,
    t: u32,
) -> Result<(SimSummary, EngineStats), String> {
    assert!(rounds > 0);
    let name = base.name().to_string();
    let tl = build_timeline(sc, net, rounds)?;
    let mut planned = plan_adaptation(base, net, profile, t, &tl, sc.seed, spec);
    let (tau, iso) = run_spliced(&mut planned.topos, &planned.phases, net, profile);
    let (mut summary, stats) = finalize(
        name,
        net,
        profile,
        rounds,
        &tl,
        tau,
        iso,
        EngineKind::Streaming,
        None,
        None,
    );
    if spec.is_active() {
        if let Some(m) = summary.scenario.as_mut() {
            m.adapt = Some(planned.metrics);
        }
    }
    Ok((summary, stats))
}

/// The naive spliced oracle: identical planning (shared
/// `plan_adaptation`), but the phases are stepped by an independent
/// plain loop — fresh [`MaskedTopology`] per phase, allocating `plan`
/// calls, its own hashed pair state — performing the same f64
/// operations in the same order as the engine's factored-out
/// [`run_spliced`] path. Every adaptive output is pinned bitwise
/// against this.
pub fn simulate_summary_adaptive_oracle(
    base: Box<dyn TopologyDesign>,
    net: &NetworkSpec,
    profile: &DatasetProfile,
    rounds: usize,
    sc: &ScenarioSpec,
    spec: &AdaptSpec,
    t: u32,
) -> Result<(SimSummary, EngineStats), String> {
    assert!(rounds > 0);
    let name = base.name().to_string();
    let tl = build_timeline(sc, net, rounds)?;
    let mut planned = plan_adaptation(base, net, profile, t, &tl, sc.seed, spec);

    let floor = profile.u as f64 * profile.t_c_ms;
    // (base_d0, backlog) per normalized pair, carried across phases.
    let mut state: HashMap<(usize, usize), (f64, f64)> = HashMap::new();
    let mut tau_series = Vec::with_capacity(rounds);
    let mut iso_series = Vec::with_capacity(rounds);
    for ph in &planned.phases {
        let mut masked = MaskedTopology::new(planned.topos[ph.topo].as_mut(), ph.offset, &ph.up);
        for r in 0..ph.len {
            let plan = masked.plan(r);
            let degrees = plan.degrees();
            let mut tau = floor;
            for &(u, v, ty) in &plan.edges {
                let key = if u <= v { (u, v) } else { (v, u) };
                let st = state.entry(key).or_insert_with(|| {
                    let d0 = pair_d0_ms(net, profile, u, v, degrees[u], degrees[v]);
                    (d0, d0 * ph.scale)
                });
                if ty == EdgeType::Strong {
                    tau = tau.max(floor.max(st.1));
                }
            }
            for &(u, v, ty) in &plan.edges {
                let key = if u <= v { (u, v) } else { (v, u) };
                let st = state.get_mut(&key).unwrap();
                match ty {
                    EdgeType::Strong => st.1 = st.0 * ph.scale,
                    EdgeType::Weak => st.1 = (st.1 - tau).max(floor),
                }
            }
            tau_series.push(tau);
            iso_series.push(plan.isolated_nodes().len() as u32);
        }
    }

    let (mut summary, stats) = finalize(
        name,
        net,
        profile,
        rounds,
        &tl,
        tau_series,
        iso_series,
        EngineKind::Streaming,
        None,
        None,
    );
    if spec.is_active() {
        if let Some(m) = summary.scenario.as_mut() {
            m.adapt = Some(planned.metrics);
        }
    }
    Ok((summary, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::zoo;
    use crate::simtime::simulate_summary_scenario_naive;
    use crate::topo::MultigraphTopology;

    fn gaia() -> (NetworkSpec, DatasetProfile) {
        (zoo::gaia(), DatasetProfile::femnist())
    }

    fn base(net: &NetworkSpec, prof: &DatasetProfile) -> Box<dyn TopologyDesign> {
        Box::new(MultigraphTopology::from_network(net, prof, 5))
    }

    fn churn() -> ScenarioSpec {
        ScenarioSpec::from_event_strs(
            9,
            &[
                "leave@40:silo=3",
                "rejoin@80:silo=3",
                "scale@100:factor=1.5",
                "outage@200:frac=0.3:dur=50",
                "scale@300:factor=1.0",
            ],
        )
        .unwrap()
    }

    fn strip_adapt(mut s: SimSummary) -> SimSummary {
        if let Some(m) = s.scenario.as_mut() {
            m.adapt = None;
        }
        s
    }

    #[test]
    fn policy_none_is_bitwise_the_pr9_tracker() {
        let (net, prof) = gaia();
        let sc = churn();
        let spec = AdaptSpec::default();
        assert!(!spec.is_active());
        let (got, stats) =
            simulate_summary_adaptive(base(&net, &prof), &net, &prof, 400, &sc, &spec, 5)
                .unwrap();
        assert_eq!(stats.kind, EngineKind::Streaming);
        let mut b = MultigraphTopology::from_network(&net, &prof, 5);
        let want = simulate_summary_scenario_naive(&mut b, &net, &prof, 400, &sc).unwrap();
        assert_eq!(got.total_ms.to_bits(), want.total_ms.to_bits());
        assert_eq!(got.scenario, want.scenario, "no adapt block under policy none");
    }

    #[test]
    fn zero_budget_warm_equals_rebuild_and_records_fallbacks() {
        let (net, prof) = gaia();
        let sc = churn();
        let warm0 = AdaptSpec { policy: AdaptPolicy::Warm, budget: 0, ..Default::default() };
        let rebuild = AdaptSpec { policy: AdaptPolicy::Rebuild, ..Default::default() };
        let (w, _) =
            simulate_summary_adaptive(base(&net, &prof), &net, &prof, 400, &sc, &warm0, 5)
                .unwrap();
        let (r, _) =
            simulate_summary_adaptive(base(&net, &prof), &net, &prof, 400, &sc, &rebuild, 5)
                .unwrap();
        let wm = w.scenario.as_ref().unwrap().adapt.clone().unwrap();
        let rm = r.scenario.as_ref().unwrap().adapt.clone().unwrap();
        assert_eq!(wm.policy, "warm");
        assert_eq!(rm.policy, "rebuild");
        assert!(wm.fallbacks > 0, "zero budget must fall down the ladder");
        assert_eq!(rm.fallbacks, 0);
        assert_eq!(wm.replans, rm.replans);
        assert_eq!(wm.evals_spent, 0);
        assert_eq!(
            strip_adapt(w).total_ms.to_bits(),
            strip_adapt(r).total_ms.to_bits(),
            "zero-budget warm must equal rebuild bitwise"
        );
    }

    #[test]
    fn engine_matches_oracle_bitwise_for_every_policy() {
        let (net, prof) = gaia();
        let sc = churn();
        for policy in [AdaptPolicy::None, AdaptPolicy::Rebuild, AdaptPolicy::Warm] {
            let spec = AdaptSpec { policy, budget: 12, eval_rounds: 30, ..Default::default() };
            let (a, sa) =
                simulate_summary_adaptive(base(&net, &prof), &net, &prof, 300, &sc, &spec, 5)
                    .unwrap();
            let (b, sb) = simulate_summary_adaptive_oracle(
                base(&net, &prof),
                &net,
                &prof,
                300,
                &sc,
                &spec,
                5,
            )
            .unwrap();
            assert_eq!(sa.kind, sb.kind);
            assert_eq!(a.total_ms.to_bits(), b.total_ms.to_bits(), "{policy:?}");
            assert_eq!(a.scenario, b.scenario, "{policy:?}: metrics must agree exactly");
        }
    }

    #[test]
    fn warm_replans_and_spends_budget_deterministically() {
        let (net, prof) = gaia();
        let sc = churn();
        let spec =
            AdaptSpec { policy: AdaptPolicy::Warm, budget: 16, eval_rounds: 40, ..Default::default() };
        let run = || {
            simulate_summary_adaptive(base(&net, &prof), &net, &prof, 400, &sc, &spec, 5)
                .unwrap()
                .0
        };
        let a = run();
        let b = run();
        assert_eq!(a.total_ms.to_bits(), b.total_ms.to_bits());
        let m = a.scenario.as_ref().unwrap().adapt.clone().unwrap();
        assert!(m.replans >= 3, "churn() changes the mask at several boundaries: {m:?}");
        assert!(m.evals_spent >= m.replans, "each replan evaluates at least the start");
        assert!(m.freeze_rounds > 0, "reconfiguration is never free");
        assert_eq!(a.scenario, b.scenario);
    }

    #[test]
    fn adapt_spec_fingerprint_splits_on_every_knob() {
        let a = AdaptSpec { policy: AdaptPolicy::Warm, ..Default::default() };
        let mut b = a.clone();
        b.budget += 1;
        let mut c = a.clone();
        c.freeze_rounds += 1;
        let mut d = a.clone();
        d.policy = AdaptPolicy::Rebuild;
        let mut e = a.clone();
        e.eval_rounds += 1;
        for (x, tag) in [(&b, "budget"), (&c, "freeze"), (&d, "policy"), (&e, "eval")] {
            assert_ne!(a.fingerprint(), x.fingerprint(), "{tag} must split the fingerprint");
        }
        assert_eq!(AdaptPolicy::parse("warm").unwrap(), AdaptPolicy::Warm);
        assert!(AdaptPolicy::parse("frob").is_err());
    }
}
