//! `mgfl optimize`: simulator-driven topology search.
//!
//! The paper hand-picks six designs and shows the multigraph wins;
//! this module treats topology as an optimization problem instead
//! (following Marfoq et al.'s framing) and uses the simulation engine
//! as a fitness oracle. A [`Genome`] — ring order, chord set, t — is
//! mutated by the moves in [`genome`], materialized as a
//! [`crate::topo::CandidateTopology`], and scored by its simulated
//! mean Eq. 5 cycle time over the spec's round budget. Chains run in
//! parallel over the sweep thread pool ([`crate::sweep::run_cells`]),
//! share a canonical-key fitness cache, and evaluate through the same
//! pooled scratch the sweep workers use
//! ([`crate::sweep::simulate_design_pooled`]), so a repeated candidate
//! costs a hash lookup.
//!
//! Determinism contract: the [`SearchReport`] is a pure function of
//! the [`OptimizeSpec`]. Chain c's RNG is
//! `named_stream(seed, "optimize/chain/{c}")`, random starts use
//! `"optimize/init/{c}"`, and the shared cache only dedups work (equal
//! keys ⇒ equal fitness bits), so thread count and scheduling never
//! change a single reported byte (`tests/search_determinism.rs`).

pub mod adapt;
pub mod genome;
pub mod spec;

pub use adapt::{
    simulate_summary_adaptive, simulate_summary_adaptive_oracle, AdaptPolicy, AdaptSpec,
};
pub use genome::{propose, random_genome, Genome};
pub use spec::{OptimizeSpec, StrategyKind};

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::TopologyKind;
use crate::graph::christofides_cycle_dense;
use crate::metrics::search::{
    BaselineRow, BudgetProbe, CandidateSummary, ChainTrace, SearchReport, TraceStep,
};
use crate::net::{DatasetProfile, NetworkSpec};
use crate::simtime::{BatchLane, CompiledTopology, LANE_WIDTH, MIN_BATCH};
use crate::store::{fitness_key, probe_key, CellStore};
use crate::sweep::spec::{cell_stream, CellSpec};
use crate::sweep::{
    run_batch_pooled, run_cells, run_cells_auto_batched, simulate_design_pooled, BuildOnce,
    RunOptions, SweepCache,
};
use crate::topo::matcha::MatchaTopology;
use crate::topo::CandidateTopology;
use crate::util::rng::{named_stream, Rng64};

/// The shared fitness oracle: genome → simulated mean cycle time, with
/// a [`BuildOnce`] cache keyed by [`Genome::canonical_fingerprint`] —
/// an allocation-free 64-bit digest of the canonical key — so any
/// candidate is simulated at most once per search, across all chains.
/// Debug builds cross-check every fingerprint against the full
/// [`Genome::canonical_key`] string, so a 64-bit collision would fail
/// loudly instead of silently aliasing two genomes. Cache sharing
/// affects cost only, never values: equal keys mean equal multigraphs
/// mean bit-equal summaries.
pub struct Evaluator<'a> {
    net: &'a NetworkSpec,
    profile: &'a DatasetProfile,
    rounds: usize,
    cache: BuildOnce<u64, f64>,
    lookups: AtomicUsize,
    /// Optional persistent store, consulted inside the build-once slot
    /// so report-visible counters (`unique_evals`/`cache_hits`) are
    /// unchanged by warm starts. Store I/O errors degrade to a miss
    /// (with one warning), never to a failed search.
    store: Option<&'a CellStore>,
    store_hits: AtomicUsize,
    store_misses: AtomicUsize,
    store_warned: AtomicBool,
    #[cfg(debug_assertions)]
    fingerprint_check: std::sync::Mutex<std::collections::HashMap<u64, String>>,
}

impl<'a> Evaluator<'a> {
    /// A fresh oracle over `(net, profile)` at `rounds` per evaluation.
    pub fn new(net: &'a NetworkSpec, profile: &'a DatasetProfile, rounds: usize) -> Self {
        Self::with_store(net, profile, rounds, None)
    }

    /// [`Self::new`] with a persistent fitness store attached: every
    /// first-in-process evaluation probes the store before simulating,
    /// and fresh results are written back, so a later `mgfl optimize`
    /// over shared cells warm-starts. Values served from the store are
    /// the exact bits a cold evaluation would produce (f64 bits
    /// roundtrip the record log), so trajectories are unchanged.
    pub fn with_store(
        net: &'a NetworkSpec,
        profile: &'a DatasetProfile,
        rounds: usize,
        store: Option<&'a CellStore>,
    ) -> Self {
        Evaluator {
            net,
            profile,
            rounds,
            cache: BuildOnce::default(),
            lookups: AtomicUsize::new(0),
            store,
            store_hits: AtomicUsize::new(0),
            store_misses: AtomicUsize::new(0),
            store_warned: AtomicBool::new(false),
            #[cfg(debug_assertions)]
            fingerprint_check: std::sync::Mutex::new(std::collections::HashMap::new()),
        }
    }

    /// The persistent-store key of `g` under this oracle's context.
    fn store_key(&self, g: &Genome) -> String {
        fitness_key(&self.net.name, &self.profile.name, self.rounds, &g.canonical_key())
    }

    /// Probe the persistent store; `None` on no-store, not-found, or
    /// I/O error (warned once).
    fn store_probe(&self, key: &str) -> Option<f64> {
        match self.store?.get_fitness(key) {
            Ok(v) => v,
            Err(e) => {
                self.warn_store_once(&e);
                None
            }
        }
    }

    /// Write a fresh fitness back to the persistent store, if any.
    fn store_write(&self, key: &str, value: f64) {
        if let Some(st) = self.store {
            if let Err(e) = st.put_fitness(key, value) {
                self.warn_store_once(&e);
            }
        }
    }

    fn warn_store_once(&self, e: &anyhow::Error) {
        if !self.store_warned.swap(true, Ordering::Relaxed) {
            eprintln!("warning: fitness store unavailable, simulating instead: {e:#}");
        }
    }

    /// Simulate `g` from scratch (the cold path under every cache).
    fn evaluate(&self, g: &Genome) -> f64 {
        let overlay = g.overlay(self.net, self.profile);
        let mut topo = CandidateTopology::new(overlay, self.net, self.profile, g.t);
        simulate_design_pooled(&mut topo, self.net, self.profile, self.rounds)
            .0
            .mean_cycle_ms
    }

    /// `g`'s cache key; in debug builds, asserts it is collision-free
    /// against every canonical key seen so far this search.
    fn fingerprinted(&self, g: &Genome) -> u64 {
        let key = g.canonical_fingerprint();
        #[cfg(debug_assertions)]
        {
            let canonical = g.canonical_key();
            let mut check = self.fingerprint_check.lock().expect("fingerprint check lock");
            let prev = check.entry(key).or_insert_with(|| canonical.clone());
            assert_eq!(
                *prev, canonical,
                "u64 fingerprint collision between distinct canonical keys"
            );
        }
        key
    }

    /// Fitness of `g`: mean Eq. 5 cycle time (ms) of its
    /// [`CandidateTopology`], simulated through the pooled-scratch
    /// engine dispatcher — bit-identical to
    /// [`crate::simtime::simulate_summary_naive`] on the same design.
    pub fn fitness(&self, g: &Genome) -> f64 {
        self.lookups.fetch_add(1, Ordering::Relaxed);
        let key = self.fingerprinted(g);
        self.cache.get_or_build(&key, || {
            let skey = self.store_key(g);
            if let Some(v) = self.store_probe(&skey) {
                self.store_hits.fetch_add(1, Ordering::Relaxed);
                return v;
            }
            if self.store.is_some() {
                self.store_misses.fetch_add(1, Ordering::Relaxed);
            }
            let v = self.evaluate(g);
            self.store_write(&skey, v);
            v
        })
    }

    /// Evaluate many genomes at once, stepping same-schedule candidates
    /// in lockstep through [`run_batch_pooled`]. Values are bit-equal
    /// to calling [`Self::fitness`] per genome — the batched engine is
    /// bitwise-identical to the solo dispatcher, and cache/fallback
    /// paths reuse the exact same code — so batching is purely a
    /// throughput lever. Used for baseline probes and the chain-start
    /// pre-pass, where many genomes are known before any is needed.
    pub fn fitness_batch(&self, genomes: &[Genome]) -> Vec<f64> {
        self.lookups.fetch_add(genomes.len(), Ordering::Relaxed);
        let keys: Vec<u64> = genomes.iter().map(|g| self.fingerprinted(g)).collect();

        // Distinct cache misses, first appearance carrying the build.
        let mut first: Vec<usize> = Vec::new();
        {
            let mut seen = std::collections::HashSet::new();
            for (i, k) in keys.iter().enumerate() {
                if self.cache.get(k).is_none() && seen.insert(*k) {
                    first.push(i);
                }
            }
        }

        // Answer what the persistent store already knows; only true
        // misses go on to compile and simulate. Hits are published
        // through the same build-once slots the cold path fills, so the
        // in-memory accounting is identical either way.
        if self.store.is_some() {
            let mut missed = Vec::with_capacity(first.len());
            for i in first {
                match self.store_probe(&self.store_key(&genomes[i])) {
                    Some(v) => {
                        self.store_hits.fetch_add(1, Ordering::Relaxed);
                        self.cache.get_or_build(&keys[i], || v);
                    }
                    None => {
                        self.store_misses.fetch_add(1, Ordering::Relaxed);
                        missed.push(i);
                    }
                }
            }
            first = missed;
        }

        // Materialize and compile each distinct miss once.
        let mut topos: Vec<(usize, CandidateTopology, Option<CompiledTopology>)> = first
            .into_iter()
            .map(|i| {
                let g = &genomes[i];
                let overlay = g.overlay(self.net, self.profile);
                let mut topo = CandidateTopology::new(overlay, self.net, self.profile, g.t);
                let ct = CompiledTopology::compile(&mut topo, self.rounds);
                (i, topo, ct)
            })
            .collect();

        // Group periodic compiles sharing one schedule; run groups of
        // MIN_BATCH+ in lockstep, everything else through the ordinary
        // dispatcher (identical bits either way).
        let mut groups: Vec<Vec<usize>> = Vec::new();
        for (mi, (_, _, ct)) in topos.iter().enumerate() {
            let Some(ct) = ct else { continue };
            let found = groups.iter_mut().find(|grp| {
                topos[grp[0]].2.as_ref().expect("groups hold periodic compiles").schedule_eq(ct)
            });
            match found {
                Some(grp) => grp.push(mi),
                None => groups.push(vec![mi]),
            }
        }
        let mut values: Vec<Option<f64>> = vec![None; topos.len()];
        for grp in groups.iter().filter(|g| g.len() >= MIN_BATCH) {
            for chunk in grp.chunks(LANE_WIDTH) {
                let rep = topos[chunk[0]].2.as_ref().expect("groups hold periodic compiles");
                let lanes: Vec<BatchLane<'_>> = chunk
                    .iter()
                    .map(|&mi| BatchLane {
                        ct: topos[mi].2.as_ref().expect("groups hold periodic compiles"),
                        net: self.net,
                        profile: self.profile,
                    })
                    .collect();
                let res = run_batch_pooled(rep, &lanes, self.rounds);
                for (&mi, (summary, _)) in chunk.iter().zip(res) {
                    values[mi] = Some(summary.mean_cycle_ms);
                }
            }
        }
        for (mi, (_, topo, _)) in topos.iter_mut().enumerate() {
            if values[mi].is_none() {
                values[mi] = Some(
                    simulate_design_pooled(topo, self.net, self.profile, self.rounds)
                        .0
                        .mean_cycle_ms,
                );
            }
        }

        // Publish through the same build-once slots fitness() uses (and
        // write fresh results back to the persistent store), then
        // answer every input (duplicates included) from the cache.
        for ((gi, _, _), v) in topos.iter().zip(&values) {
            let v = (*v).expect("every distinct miss was evaluated");
            self.cache.get_or_build(&keys[*gi], || v);
            self.store_write(&self.store_key(&genomes[*gi]), v);
        }
        keys.iter()
            .map(|k| self.cache.get(k).expect("all keys evaluated above"))
            .collect()
    }

    /// Distinct genomes actually simulated.
    pub fn unique_evals(&self) -> usize {
        self.cache.entries()
    }

    /// Fitness lookups served from the cache (lookups − unique). Both
    /// counts are thread-count invariant: each chain's trajectory — and
    /// so its lookup sequence — is a pure function of the spec.
    pub fn cache_hits(&self) -> usize {
        self.lookups.load(Ordering::Relaxed) - self.cache.entries()
    }

    /// First-in-process evaluations answered by the persistent store.
    pub fn store_hits(&self) -> usize {
        self.store_hits.load(Ordering::Relaxed)
    }

    /// First-in-process evaluations the store missed (simulated and
    /// written back). 0 when no store is attached.
    pub fn store_misses(&self) -> usize {
        self.store_misses.load(Ordering::Relaxed)
    }
}

/// One accepted transition in a chain (search-side view; the report
/// stores [`crate::metrics::search::TraceStep`]).
#[derive(Debug, Clone)]
pub struct ChainStep {
    /// Proposal step (0 = start marker).
    pub step: usize,
    /// Move name, or `start` / `restart`.
    pub mv: &'static str,
    /// Fitness after the transition, ms.
    pub fitness_ms: f64,
}

/// The outcome of one chain.
#[derive(Debug, Clone)]
pub struct ChainResult {
    /// Chain index.
    pub chain: usize,
    /// The genome the chain started from.
    pub start: Genome,
    /// Fitness of `start`, ms.
    pub start_fitness_ms: f64,
    /// Best genome the chain ever held.
    pub best: Genome,
    /// Fitness of `best`, ms.
    pub best_fitness_ms: f64,
    /// Accepted-move trace, beginning with the `start` marker.
    pub trace: Vec<ChainStep>,
    /// True when the chain stopped at the wall-clock deadline before
    /// consuming its full step budget ([`OptimizeSpec::deadline_ms`]).
    pub exhausted: bool,
}

/// A chain driver: consumes `steps` proposals from the chain's own RNG
/// stream and returns the trajectory. Implementations must draw RNG
/// values in a fixed order per step so runs are reproducible.
pub trait SearchStrategy: Sync {
    /// Spec/report spelling of the strategy.
    fn name(&self) -> &'static str;

    /// Run chain `chain` from `start` to completion — or until
    /// `deadline` passes, whichever comes first. A deadline stop is
    /// graceful: the chain keeps everything accepted so far and marks
    /// [`ChainResult::exhausted`]. `None` (the `deadline_ms = 0`
    /// default) never stops early, preserving the pure-function-of-spec
    /// determinism contract.
    fn run_chain(
        &self,
        chain: usize,
        start: Genome,
        ev: &Evaluator<'_>,
        spec: &OptimizeSpec,
        deadline: Option<Instant>,
    ) -> ChainResult;
}

/// True once `deadline` (if any) has passed. Checked between proposal
/// steps so a stop never tears a half-evaluated transition.
fn past_deadline(deadline: Option<Instant>) -> bool {
    deadline.is_some_and(|d| Instant::now() >= d)
}

/// The chain's deterministic RNG: stream `"optimize/chain/{c}"` of the
/// spec seed, independent of every other chain and of execution order.
fn chain_rng(spec: &OptimizeSpec, chain: usize) -> Rng64 {
    Rng64::seed_from_u64(named_stream(spec.seed, &format!("optimize/chain/{chain}")))
}

/// Greedy hill-climbing: accept strictly-improving proposals only;
/// after `restart_after` consecutive rejections, jump to a fresh
/// random genome (drawn from the same chain stream) and keep going.
pub struct HillClimb;

impl SearchStrategy for HillClimb {
    fn name(&self) -> &'static str {
        "hill"
    }

    fn run_chain(
        &self,
        chain: usize,
        start: Genome,
        ev: &Evaluator<'_>,
        spec: &OptimizeSpec,
        deadline: Option<Instant>,
    ) -> ChainResult {
        let n = start.order.len();
        let mut rng = chain_rng(spec, chain);
        let mut cur = start.clone();
        let mut f_cur = ev.fitness(&cur);
        let start_fitness_ms = f_cur;
        let mut best = cur.clone();
        let mut f_best = f_cur;
        let mut trace = vec![ChainStep { step: 0, mv: "start", fitness_ms: f_cur }];
        let mut stall = 0usize;
        let mut exhausted = false;
        for step in 1..=spec.steps {
            if past_deadline(deadline) {
                exhausted = true;
                break;
            }
            let Some((g, mv)) = propose(&cur, &mut rng, n, spec) else {
                continue;
            };
            let f = ev.fitness(&g);
            if f < f_cur {
                cur = g;
                f_cur = f;
                stall = 0;
                trace.push(ChainStep { step, mv, fitness_ms: f });
                if f < f_best {
                    best = cur.clone();
                    f_best = f;
                }
            } else {
                stall += 1;
                if stall >= spec.restart_after {
                    cur = random_genome(&mut rng, n, spec);
                    f_cur = ev.fitness(&cur);
                    stall = 0;
                    trace.push(ChainStep { step, mv: "restart", fitness_ms: f_cur });
                    if f_cur < f_best {
                        best = cur.clone();
                        f_best = f_cur;
                    }
                }
            }
        }
        ChainResult {
            chain,
            start,
            start_fitness_ms,
            best,
            best_fitness_ms: f_best,
            trace,
            exhausted,
        }
    }
}

/// Simulated annealing: geometric cooling (`temp *= alpha` each step),
/// Metropolis acceptance `exp(-(f - f_cur) / temp)` for worsening
/// proposals. The acceptance draw is taken only for non-improving
/// proposals (short-circuit), which is part of the RNG contract.
pub struct Anneal;

impl SearchStrategy for Anneal {
    fn name(&self) -> &'static str {
        "anneal"
    }

    fn run_chain(
        &self,
        chain: usize,
        start: Genome,
        ev: &Evaluator<'_>,
        spec: &OptimizeSpec,
        deadline: Option<Instant>,
    ) -> ChainResult {
        let n = start.order.len();
        let mut rng = chain_rng(spec, chain);
        let mut cur = start.clone();
        let mut f_cur = ev.fitness(&cur);
        let start_fitness_ms = f_cur;
        let mut best = cur.clone();
        let mut f_best = f_cur;
        let mut trace = vec![ChainStep { step: 0, mv: "start", fitness_ms: f_cur }];
        let mut temp = spec.anneal_t0;
        let mut exhausted = false;
        for step in 1..=spec.steps {
            if past_deadline(deadline) {
                exhausted = true;
                break;
            }
            temp *= spec.anneal_alpha;
            let Some((g, mv)) = propose(&cur, &mut rng, n, spec) else {
                continue;
            };
            let f = ev.fitness(&g);
            let accept = f < f_cur || rng.gen_f64() < (-(f - f_cur) / temp).exp();
            if accept {
                cur = g;
                f_cur = f;
                trace.push(ChainStep { step, mv, fitness_ms: f });
                if f < f_best {
                    best = cur.clone();
                    f_best = f;
                }
            }
        }
        ChainResult {
            chain,
            start,
            start_fitness_ms,
            best,
            best_fitness_ms: f_best,
            trace,
            exhausted,
        }
    }
}

/// A finished search: the deterministic report plus host-side stats
/// (which deliberately stay out of the artifacts, mirroring
/// [`crate::sweep::SweepOutcome`]).
#[derive(Debug, Clone)]
pub struct SearchOutcome {
    /// The deterministic artifact (pure function of the spec).
    pub report: SearchReport,
    /// Wall-clock of the whole search, ms.
    pub host_elapsed_ms: f64,
    /// Worker threads the chains ran on.
    pub threads: usize,
    /// Evaluations (genomes, baselines, budget probes) answered by the
    /// persistent store. Host-side only — never in the report, which
    /// stays a pure function of the spec.
    pub store_hits: usize,
    /// Store probes that missed (simulated, then written back). 0 when
    /// no store is attached.
    pub store_misses: usize,
}

fn summarize(g: &Genome, fitness_ms: f64) -> CandidateSummary {
    CandidateSummary {
        order: g.order.clone(),
        chords: g.chords.clone(),
        t: g.t,
        key: g.canonical_key(),
        mean_cycle_ms: fitness_ms,
    }
}

/// The genome chain 0 starts from: the paper's Christofides ring at
/// `baseline_t` (clamped into the search's t range), no chords. Its
/// fitness is bit-identical to the paper-multigraph baseline —
/// [`crate::graph::ring_overlay_dense`] emits exactly these
/// consecutive-pair edges — so the searched best can never lose to the
/// paper design under hill-climbing.
pub fn paper_start(net: &NetworkSpec, profile: &DatasetProfile, spec: &OptimizeSpec) -> Genome {
    let cycle = christofides_cycle_dense(&net.connectivity_dense(profile));
    Genome {
        order: cycle,
        chords: Vec::new(),
        t: spec.baseline_t.clamp(spec.t_min, spec.t_max),
    }
}

/// Run the full search: baselines through the literal sweep-cell cache
/// path, then all chains in parallel over the shared fitness oracle,
/// then the MATCHA budget probes. Returns the report plus host stats.
pub fn run(spec: &OptimizeSpec, opts: &RunOptions) -> Result<SearchOutcome> {
    run_with_store(spec, opts, None)
}

/// [`run`] with an optional persistent [`CellStore`]: baseline cells,
/// genome fitness, and MATCHA budget probes are all read through (and
/// written back to) the store, so a repeated `mgfl optimize` — or one
/// sharing cells with earlier sweeps — warm-starts. The report is
/// byte-identical to a cold run; only the [`SearchOutcome`] host-side
/// counters observe the store.
pub fn run_with_store(
    spec: &OptimizeSpec,
    opts: &RunOptions,
    store: Option<&CellStore>,
) -> Result<SearchOutcome> {
    let spec = {
        let mut s = spec.clone();
        s.canonicalize()?;
        s
    };
    spec.validate()?;
    let net = crate::net::by_name(&spec.network).expect("validated network");
    let profile = DatasetProfile::by_name(&spec.profile).expect("validated profile");
    let n = net.n();
    let t0 = Instant::now();

    // Baselines go through run_cells_auto_batched — the same schedule
    // cache and batch planner the sweep engine uses — so an optimize
    // report's baseline row is bit-identical to the equivalent sweep
    // cell whether the probes batch (structurally equal schedules) or
    // fall back to per-cell runs.
    let cache = SweepCache::default();
    let baseline_cells: Vec<CellSpec> = [TopologyKind::Multigraph, TopologyKind::Ring]
        .iter()
        .map(|&kind| CellSpec {
            index: 0,
            topology: kind,
            network: spec.network.clone(),
            profile: spec.profile.clone(),
            t: spec.baseline_t,
            base_seed: spec.seed,
            cell_seed: cell_stream(spec.seed, kind, &spec.network, &spec.profile, spec.baseline_t),
            rounds: spec.rounds,
            scenario: None,
            adapt: None,
        })
        .collect();
    let mut aux_store_hits = 0usize;
    let mut aux_store_misses = 0usize;
    let mut baseline_rows: Vec<Option<BaselineRow>> =
        baseline_cells.iter().map(|_| None).collect();
    let mut baseline_missed: Vec<usize> = Vec::new();
    for (i, cell) in baseline_cells.iter().enumerate() {
        if let Some(st) = store {
            if let Some(sc) = st.get_cell(&cell.fingerprint())? {
                baseline_rows[i] = Some(BaselineRow {
                    topology: sc.topology,
                    t: cell.t,
                    mean_cycle_ms: sc.mean_cycle_ms,
                });
                aux_store_hits += 1;
                continue;
            }
            aux_store_misses += 1;
        }
        baseline_missed.push(i);
    }
    let missed_cells: Vec<CellSpec> =
        baseline_missed.iter().map(|&i| baseline_cells[i].clone()).collect();
    for (&i, (s, _, stats)) in
        baseline_missed.iter().zip(run_cells_auto_batched(&missed_cells, &cache))
    {
        if let Some(st) = store {
            st.put_cell(&baseline_cells[i].fingerprint(), &s, &stats)?;
        }
        baseline_rows[i] = Some(BaselineRow {
            topology: s.topology,
            t: baseline_cells[i].t,
            mean_cycle_ms: s.mean_cycle_ms,
        });
    }
    let baselines: Vec<BaselineRow> =
        baseline_rows.into_iter().map(|r| r.expect("every baseline ran or hit")).collect();
    let multigraph_baseline_ms = baselines[0].mean_cycle_ms;

    // Chain starts: chain 0 from the paper design, the rest random,
    // each from its own "optimize/init/{c}" stream (separate from the
    // chain's proposal stream so adding steps never reshuffles starts).
    let starts: Vec<Genome> = (0..spec.chains)
        .map(|c| {
            if c == 0 {
                paper_start(&net, &profile, &spec)
            } else {
                let mut rng =
                    Rng64::seed_from_u64(named_stream(spec.seed, &format!("optimize/init/{c}")));
                random_genome(&mut rng, n, &spec)
            }
        })
        .collect();

    let strategy: &dyn SearchStrategy = match spec.strategy {
        StrategyKind::Hill => &HillClimb,
        StrategyKind::Anneal => &Anneal,
    };
    let ev = Evaluator::with_store(&net, &profile, spec.rounds, store);
    // Pre-evaluate every chain start as one batch: starts that share a
    // schedule (duplicate random genomes, or distinct rings whose
    // multigraphs coincide) run in lockstep lanes, and each chain's
    // opening fitness() call becomes a cache hit. Values are bit-equal
    // to the solo path, so chain trajectories are unchanged.
    let _ = ev.fitness_batch(&starts);
    // The wall-clock deadline (if any) covers the whole search, not
    // each chain: every chain races the same instant, measured from
    // run start so baseline time counts against the budget too.
    let deadline =
        (spec.deadline_ms > 0).then(|| t0 + Duration::from_millis(spec.deadline_ms));
    let inner = RunOptions { threads: opts.threads, progress: false, dedup: true };
    let results: Vec<ChainResult> = run_cells(&starts, &inner, |i, start| {
        strategy.run_chain(i, start.clone(), &ev, &spec, deadline)
    });
    let threads = crate::sweep::effective_threads(opts.threads, starts.len());

    // Winner: minimum best fitness, first chain wins ties.
    let mut best_chain = 0usize;
    for (i, r) in results.iter().enumerate() {
        if r.best_fitness_ms < results[best_chain].best_fitness_ms {
            best_chain = i;
        }
    }
    let best = summarize(&results[best_chain].best, results[best_chain].best_fitness_ms);
    let improvement_pct = 100.0 * (1.0 - best.mean_cycle_ms / multigraph_baseline_ms);

    // MATCHA budget probes: reported alongside, never a search winner
    // (a different design family; listed for the comparison table).
    let mut budget_probes: Vec<BudgetProbe> = Vec::with_capacity(spec.matcha_budgets.len());
    for &budget in &spec.matcha_budgets {
        let seed = named_stream(spec.seed, &format!("optimize/matcha/{budget}"));
        let key = probe_key(&spec.network, &spec.profile, spec.rounds, budget, seed);
        let stored_ms = match store {
            Some(st) => st.get_fitness(&key)?,
            None => None,
        };
        let mean_cycle_ms = match stored_ms {
            Some(ms) => {
                aux_store_hits += 1;
                ms
            }
            None => {
                if store.is_some() {
                    aux_store_misses += 1;
                }
                let mut topo = MatchaTopology::new(&net, &profile, budget, seed);
                let (s, _) = simulate_design_pooled(&mut topo, &net, &profile, spec.rounds);
                if let Some(st) = store {
                    st.put_fitness(&key, s.mean_cycle_ms)?;
                }
                s.mean_cycle_ms
            }
        };
        budget_probes.push(BudgetProbe { budget, mean_cycle_ms });
    }

    let chains: Vec<ChainTrace> = results
        .iter()
        .map(|r| ChainTrace {
            chain: r.chain,
            start: summarize(&r.start, r.start_fitness_ms),
            best: summarize(&r.best, r.best_fitness_ms),
            accepted: r.trace.len().saturating_sub(1),
            trace: r
                .trace
                .iter()
                .map(|s| TraceStep {
                    step: s.step,
                    mv: s.mv.to_string(),
                    fitness_ms: s.fitness_ms,
                })
                .collect(),
        })
        .collect();

    let report = SearchReport {
        name: spec.name.clone(),
        network: spec.network.clone(),
        profile: spec.profile.clone(),
        strategy: spec.strategy.as_str().to_string(),
        rounds: spec.rounds,
        seed: spec.seed,
        chains,
        baselines,
        budget_probes,
        best_chain,
        best,
        improvement_pct,
        unique_evals: ev.unique_evals(),
        cache_hits: ev.cache_hits(),
        budget_exhausted: results.iter().any(|r| r.exhausted),
    };
    Ok(SearchOutcome {
        report,
        host_elapsed_ms: t0.elapsed().as_secs_f64() * 1e3,
        threads,
        store_hits: ev.store_hits() + aux_store_hits,
        store_misses: ev.store_misses() + aux_store_misses,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::zoo;

    fn tiny_spec() -> OptimizeSpec {
        OptimizeSpec {
            name: "tiny".into(),
            rounds: 60,
            chains: 2,
            steps: 30,
            restart_after: 10,
            ..Default::default()
        }
    }

    #[test]
    fn chain0_start_matches_the_multigraph_baseline_bitwise() {
        let spec = tiny_spec();
        let outcome = run(&spec, &RunOptions { threads: 1, ..Default::default() }).unwrap();
        let r = &outcome.report;
        assert_eq!(r.baselines[0].topology, "multigraph");
        assert_eq!(
            r.chains[0].start.mean_cycle_ms.to_bits(),
            r.baselines[0].mean_cycle_ms.to_bits(),
            "chain 0 must start exactly at the paper design"
        );
        // Hill-climbing only ever improves, so the winner can't lose.
        assert!(r.best.mean_cycle_ms <= r.baselines[0].mean_cycle_ms);
        assert!(r.improvement_pct >= 0.0);
        assert!(!r.budget_exhausted, "no deadline: the full step budget ran");
    }

    #[test]
    fn an_expired_deadline_stops_chains_gracefully() {
        let net = zoo::gaia();
        let p = DatasetProfile::femnist();
        let spec = tiny_spec();
        let ev = Evaluator::new(&net, &p, spec.rounds);
        let start = paper_start(&net, &p, &spec);

        // A deadline that has already passed: both strategies keep the
        // start marker, spend zero proposals, and flag exhaustion.
        for strategy in [&HillClimb as &dyn SearchStrategy, &Anneal] {
            let r = strategy.run_chain(0, start.clone(), &ev, &spec, Some(Instant::now()));
            assert!(r.exhausted, "{}: expired deadline must stop the chain", strategy.name());
            assert_eq!(r.trace.len(), 1, "{}: only the start marker", strategy.name());
            assert_eq!(r.best_fitness_ms.to_bits(), r.start_fitness_ms.to_bits());
        }

        // No deadline (the deadline_ms = 0 default) never exhausts.
        let r = HillClimb.run_chain(0, start, &ev, &spec, None);
        assert!(!r.exhausted);
        assert!(r.trace.len() > 1, "the tiny spec accepts at least one move");
    }

    #[test]
    fn evaluator_dedups_by_canonical_key() {
        let net = zoo::gaia();
        let p = DatasetProfile::femnist();
        let ev = Evaluator::new(&net, &p, 40);
        let g = Genome { order: (0..net.n()).collect(), chords: vec![], t: 5 };
        let mut rev: Vec<usize> = g.order.clone();
        rev[1..].reverse();
        let g_rev = Genome { order: rev, chords: vec![], t: 5 };
        let f1 = ev.fitness(&g);
        let f2 = ev.fitness(&g);
        let f3 = ev.fitness(&g_rev);
        assert_eq!(f1.to_bits(), f2.to_bits());
        assert_eq!(f1.to_bits(), f3.to_bits(), "reversed ring is the same overlay");
        assert_eq!(ev.unique_evals(), 1);
        assert_eq!(ev.cache_hits(), 2);
    }

    #[test]
    fn fitness_batch_is_bitwise_equal_to_solo_fitness() {
        let net = zoo::gaia();
        let p = DatasetProfile::femnist();
        let n = net.n();
        let spec = OptimizeSpec::default();
        // A population with deliberate duplicates: same-t ring copies
        // batch in lockstep lanes, the reversed ring dedups by
        // fingerprint, and the random genomes exercise the fallback.
        let ring = Genome { order: (0..n).collect(), chords: vec![], t: 5 };
        let mut rev: Vec<usize> = ring.order.clone();
        rev[1..].reverse();
        let mut pop = vec![
            ring.clone(),
            Genome { order: rev, chords: vec![], t: 5 },
            Genome { order: (0..n).collect(), chords: vec![], t: 3 },
            ring.clone(),
        ];
        let mut rng = Rng64::seed_from_u64(named_stream(5, "batch-test"));
        for _ in 0..4 {
            pop.push(random_genome(&mut rng, n, &spec));
        }

        let batch_ev = Evaluator::new(&net, &p, 60);
        let batch = batch_ev.fitness_batch(&pop);
        let solo_ev = Evaluator::new(&net, &p, 60);
        for (g, &f) in pop.iter().zip(&batch) {
            assert_eq!(
                f.to_bits(),
                solo_ev.fitness(g).to_bits(),
                "batched fitness must be bit-equal to the solo path for {}",
                g.canonical_key()
            );
        }
        // Same dedup accounting as the solo evaluator, in one call.
        assert_eq!(batch_ev.unique_evals(), solo_ev.unique_evals());
        assert_eq!(
            batch_ev.cache_hits(),
            pop.len() - batch_ev.unique_evals(),
            "every duplicate input is a cache hit"
        );
        // A second batch over the same population is all hits.
        let again = batch_ev.fitness_batch(&pop);
        for (a, b) in batch.iter().zip(&again) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(batch_ev.unique_evals(), solo_ev.unique_evals());
    }

    #[test]
    fn strategies_have_matching_names() {
        assert_eq!(HillClimb.name(), StrategyKind::Hill.as_str());
        assert_eq!(Anneal.name(), StrategyKind::Anneal.as_str());
    }

    #[test]
    fn anneal_runs_and_reports() {
        let spec = OptimizeSpec { strategy: StrategyKind::Anneal, ..tiny_spec() };
        let outcome = run(&spec, &RunOptions { threads: 2, ..Default::default() }).unwrap();
        let r = &outcome.report;
        assert_eq!(r.strategy, "anneal");
        assert_eq!(r.chains.len(), 2);
        // Annealing can wander uphill, but best is tracked separately
        // and chain 0 starts at the baseline, so best <= baseline.
        assert!(r.best.mean_cycle_ms <= r.baselines[0].mean_cycle_ms);
        for c in &r.chains {
            assert_eq!(c.trace[0].mv, "start");
            assert_eq!(c.accepted, c.trace.len() - 1);
        }
    }

    #[test]
    fn budget_probes_ride_in_the_report() {
        let spec = OptimizeSpec {
            matcha_budgets: vec![0.5, 1.0],
            chains: 1,
            steps: 5,
            rounds: 40,
            ..tiny_spec()
        };
        let outcome = run(&spec, &RunOptions { threads: 1, ..Default::default() }).unwrap();
        let probes = &outcome.report.budget_probes;
        assert_eq!(probes.len(), 2);
        assert_eq!(probes[0].budget, 0.5);
        assert!(probes.iter().all(|p| p.mean_cycle_ms > 0.0));
    }
}
