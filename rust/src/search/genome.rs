//! The search space: a genome is (ring order, chord set, t).
//!
//! Every genome describes a connected overlay — a Hamiltonian ring in
//! `order` plus optional chord edges — and the Algorithm-1 parameter
//! `t`. The move set mutates all three: `two_opt`/`or_opt` reorder the
//! ring (classic TSP neighborhoods), `t_up`/`t_down` step the edge
//! multiplicity cap, `chord_add`/`chord_drop` edit the chord set under
//! the spec's degree bound. RNG consumption order is part of the
//! determinism contract (`tests/search_determinism.rs` pins report
//! bytes): a proposal that turns out invalid still consumed exactly the
//! draws it made before failing.

use crate::graph::Graph;
use crate::net::{DatasetProfile, NetworkSpec};
use crate::util::rng::Rng64;

use super::spec::OptimizeSpec;

/// One point of the search space: a ring permutation (always starting
/// at silo 0 — rotations are equivalent, so the anchor costs nothing),
/// a sorted chord list (`u < v`, not ring edges), and Algorithm 1's t.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Genome {
    /// Ring visit order; `order[0] == 0` always.
    pub order: Vec<usize>,
    /// Extra overlay edges beyond the ring, sorted, each `u < v`.
    pub chords: Vec<(usize, usize)>,
    /// Algorithm 1's max edge multiplicity for this candidate.
    pub t: u32,
}

impl Genome {
    /// Canonical cache key: ring direction is normalized (a ring read
    /// backwards is the same overlay), chords are already sorted, and
    /// `t` is appended — so two genomes with equal keys build identical
    /// multigraphs and therefore identical fitness bits. The key's
    /// insertion-order independence is safe because overlay edge order
    /// never changes fitness: Eq. 4/5 reduce edges through `f64::max`
    /// and per-edge state, both order-independent.
    pub fn canonical_key(&self) -> String {
        let o = &self.order;
        debug_assert_eq!(o[0], 0, "genome ring must be anchored at silo 0");
        let canon: Vec<usize> = if o.len() > 2 && o[1] > o[o.len() - 1] {
            let mut v = Vec::with_capacity(o.len());
            v.push(o[0]);
            v.extend(o[1..].iter().rev().copied());
            v
        } else {
            o.clone()
        };
        let order_s =
            canon.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(",");
        let chord_s = self
            .chords
            .iter()
            .map(|(u, v)| format!("{u}-{v}"))
            .collect::<Vec<_>>()
            .join(";");
        format!("overlay/o={order_s};c={chord_s};t={}", self.t)
    }

    /// Allocation-free FNV-1a fingerprint of the *content* of
    /// [`Self::canonical_key`]: the same ring-direction normalization,
    /// the same components (order, chords, t) in the same sequence,
    /// hashed directly instead of formatted into a `String`. Component
    /// lengths are mixed in as prefixes, so the (order, chords)
    /// boundary is unambiguous and equal fingerprints mean equal
    /// canonical keys up to 64-bit collisions — which the evaluator
    /// cross-checks against the string key in debug builds.
    pub fn canonical_fingerprint(&self) -> u64 {
        fn mix(mut h: u64, x: u64) -> u64 {
            for b in x.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x100000001B3);
            }
            h
        }
        let o = &self.order;
        debug_assert_eq!(o[0], 0, "genome ring must be anchored at silo 0");
        let mut h = 0xCBF29CE484222325u64;
        h = mix(h, o.len() as u64);
        if o.len() > 2 && o[1] > o[o.len() - 1] {
            h = mix(h, o[0] as u64);
            for &x in o[1..].iter().rev() {
                h = mix(h, x as u64);
            }
        } else {
            for &x in o {
                h = mix(h, x as u64);
            }
        }
        h = mix(h, self.chords.len() as u64);
        for &(u, v) in &self.chords {
            h = mix(h, u as u64);
            h = mix(h, v as u64);
        }
        mix(h, self.t as u64)
    }

    /// Materialize the overlay graph (ring edges in order, then chords)
    /// with Eq. 3 degree-1 connectivity weights — the same weights the
    /// paper's overlay carries; Algorithm 1 recomputes true delays from
    /// overlay degrees, so the stored weights are bookkeeping only.
    pub fn overlay(&self, net: &NetworkSpec, profile: &DatasetProfile) -> Graph {
        let mut g = Graph::new(net.n());
        let k = self.order.len();
        for i in 0..k {
            let (u, v) = (self.order[i], self.order[(i + 1) % k]);
            g.add_edge(u, v, net.conn_weight(profile, u, v));
        }
        for &(u, v) in &self.chords {
            g.add_edge(u, v, net.conn_weight(profile, u, v));
        }
        g
    }

    /// Overlay degree of every node (ring contributes 2 each, chords 1
    /// per endpoint) — what `chord_add` checks against `max_degree`.
    pub fn degrees(&self, n: usize) -> Vec<usize> {
        let mut deg = vec![0usize; n];
        let k = self.order.len();
        for i in 0..k {
            deg[self.order[i]] += 1;
            deg[self.order[(i + 1) % k]] += 1;
        }
        for &(u, v) in &self.chords {
            deg[u] += 1;
            deg[v] += 1;
        }
        deg
    }

    /// Whether `(u, v)` (normalized `u < v`) is one of the ring edges.
    fn has_ring_pair(&self, u: usize, v: usize) -> bool {
        let k = self.order.len();
        (0..k).any(|i| {
            let (a, b) = (self.order[i], self.order[(i + 1) % k]);
            (a.min(b), a.max(b)) == (u, v)
        })
    }
}

/// Propose one mutation of `genome`. Returns `None` when the drawn move
/// is invalid in this state (t at its bound, chord duplicate/degree
/// violation, empty chord list) — the chain treats that as a skipped
/// step. The kind is drawn uniformly from the moves the spec enables;
/// each arm's RNG draws are fixed per kind (see module docs).
pub fn propose(
    genome: &Genome,
    rng: &mut Rng64,
    n: usize,
    spec: &OptimizeSpec,
) -> Option<(Genome, &'static str)> {
    let mut kinds: Vec<&'static str> = vec!["two_opt", "or_opt"];
    if spec.t_min < spec.t_max {
        kinds.push("t_up");
        kinds.push("t_down");
    }
    if spec.max_degree > 2 {
        kinds.push("chord_add");
        kinds.push("chord_drop");
    }
    let kind = kinds[rng.gen_range(0, kinds.len())];
    let mut g = genome.clone();
    match kind {
        "two_opt" => {
            // Reverse a segment that never includes the anchor 0.
            let i = rng.gen_range(1, n - 1);
            let j = rng.gen_range(i + 1, n);
            g.order[i..=j].reverse();
            Some((g, kind))
        }
        "or_opt" => {
            // Relocate one node to another position past the anchor.
            let i = rng.gen_range(1, n);
            let j = rng.gen_range(1, n);
            let node = g.order.remove(i);
            let pos = j.min(g.order.len());
            g.order.insert(pos, node);
            Some((g, kind))
        }
        "t_up" => {
            if g.t >= spec.t_max {
                return None;
            }
            g.t += 1;
            Some((g, kind))
        }
        "t_down" => {
            if g.t <= spec.t_min {
                return None;
            }
            g.t -= 1;
            Some((g, kind))
        }
        "chord_add" => {
            let u = rng.gen_range(0, n);
            let v = rng.gen_range(0, n);
            if u == v {
                return None;
            }
            let (u, v) = (u.min(v), u.max(v));
            if g.has_ring_pair(u, v) || g.chords.contains(&(u, v)) {
                return None;
            }
            let deg = g.degrees(n);
            if deg[u] >= spec.max_degree || deg[v] >= spec.max_degree {
                return None;
            }
            g.chords.push((u, v));
            g.chords.sort_unstable();
            Some((g, kind))
        }
        "chord_drop" => {
            if g.chords.is_empty() {
                return None;
            }
            let i = rng.gen_range(0, g.chords.len());
            g.chords.remove(i);
            Some((g, kind))
        }
        _ => unreachable!("kind drawn from the kinds list"),
    }
}

/// A uniformly random genome: shuffled ring order (anchor fixed at 0),
/// uniform `t` in `[t_min, t_max]`, no chords. Used for chain starts
/// (chains past 0) and hill-climbing restarts.
pub fn random_genome(rng: &mut Rng64, n: usize, spec: &OptimizeSpec) -> Genome {
    let mut rest: Vec<usize> = (1..n).collect();
    rng.shuffle(&mut rest);
    let t = spec.t_min + rng.gen_range(0, (spec.t_max - spec.t_min + 1) as usize) as u32;
    let mut order = Vec::with_capacity(n);
    order.push(0);
    order.extend(rest);
    Genome { order, chords: Vec::new(), t }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::named_stream;

    fn spec() -> OptimizeSpec {
        OptimizeSpec::default()
    }

    #[test]
    fn canonical_key_normalizes_ring_direction() {
        let a = Genome { order: vec![0, 1, 2, 3], chords: vec![], t: 5 };
        let b = Genome { order: vec![0, 3, 2, 1], chords: vec![], t: 5 };
        assert_eq!(a.canonical_key(), b.canonical_key());
        let c = Genome { order: vec![0, 1, 2, 3], chords: vec![], t: 4 };
        assert_ne!(a.canonical_key(), c.canonical_key(), "t is part of the key");
        let d = Genome { order: vec![0, 1, 2, 3], chords: vec![(0, 2)], t: 5 };
        assert_ne!(a.canonical_key(), d.canonical_key(), "chords are part of the key");
        assert_eq!(a.canonical_key(), "overlay/o=0,1,2,3;c=;t=5");
        assert_eq!(d.canonical_key(), "overlay/o=0,1,2,3;c=0-2;t=5");
    }

    #[test]
    fn canonical_fingerprint_mirrors_the_canonical_key() {
        // Same normalization as the string key: a reversed ring is the
        // same overlay; t and chords split the fingerprint.
        let a = Genome { order: vec![0, 1, 2, 3], chords: vec![], t: 5 };
        let b = Genome { order: vec![0, 3, 2, 1], chords: vec![], t: 5 };
        assert_eq!(a.canonical_fingerprint(), b.canonical_fingerprint());
        let c = Genome { order: vec![0, 1, 2, 3], chords: vec![], t: 4 };
        assert_ne!(a.canonical_fingerprint(), c.canonical_fingerprint());
        let d = Genome { order: vec![0, 1, 2, 3], chords: vec![(0, 2)], t: 5 };
        assert_ne!(a.canonical_fingerprint(), d.canonical_fingerprint());
        let e = Genome { order: vec![0, 2, 1, 3], chords: vec![], t: 5 };
        assert_ne!(a.canonical_fingerprint(), e.canonical_fingerprint());

        // Key-equality ⇔ fingerprint-equality over a random population.
        let spec = spec();
        let mut rng = Rng64::seed_from_u64(named_stream(11, "fp-test"));
        let genomes: Vec<Genome> = (0..200).map(|_| random_genome(&mut rng, 7, &spec)).collect();
        for x in &genomes {
            for y in &genomes {
                assert_eq!(
                    x.canonical_key() == y.canonical_key(),
                    x.canonical_fingerprint() == y.canonical_fingerprint(),
                    "{} vs {}",
                    x.canonical_key(),
                    y.canonical_key()
                );
            }
        }
    }

    #[test]
    fn overlay_and_degrees_agree() {
        let net = crate::net::zoo::gaia();
        let p = DatasetProfile::femnist();
        let g = Genome {
            order: (0..net.n()).collect(),
            chords: vec![(0, 5), (2, 7)],
            t: 5,
        };
        let ov = g.overlay(&net, &p);
        assert!(ov.is_connected());
        assert_eq!(ov.edges().len(), net.n() + 2);
        let deg = g.degrees(net.n());
        for u in 0..net.n() {
            assert_eq!(ov.degree(u), deg[u], "node {u}");
        }
        assert_eq!(deg[0], 3);
        assert_eq!(deg[1], 2);
    }

    #[test]
    fn proposals_keep_invariants() {
        let spec = spec();
        let n = 11;
        let mut rng = Rng64::seed_from_u64(named_stream(7, "genome-test"));
        let mut cur = random_genome(&mut rng, n, &spec);
        let mut seen_kinds = std::collections::BTreeSet::new();
        let mut valid = 0;
        for _ in 0..2000 {
            if let Some((g, kind)) = propose(&cur, &mut rng, n, &spec) {
                seen_kinds.insert(kind);
                valid += 1;
                assert_eq!(g.order[0], 0, "anchor must survive {kind}");
                let mut sorted = g.order.clone();
                sorted.sort_unstable();
                assert_eq!(sorted, (0..n).collect::<Vec<_>>(), "{kind} broke the permutation");
                assert!((spec.t_min..=spec.t_max).contains(&g.t), "{kind} broke t bounds");
                let mut chords_sorted = g.chords.clone();
                chords_sorted.sort_unstable();
                assert_eq!(chords_sorted, g.chords, "{kind} left chords unsorted");
                for &(u, v) in &g.chords {
                    assert!(u < v);
                    assert!(!g.has_ring_pair(u, v), "{kind} duplicated a ring edge");
                }
                let deg = g.degrees(n);
                assert!(
                    deg.iter().all(|&d| d <= spec.max_degree),
                    "{kind} violated max_degree: {deg:?}"
                );
                cur = g;
            }
        }
        assert!(valid > 1000, "most proposals should be valid ({valid}/2000)");
        for kind in ["two_opt", "or_opt", "t_up", "t_down", "chord_add", "chord_drop"] {
            assert!(seen_kinds.contains(kind), "move {kind} never accepted-proposed");
        }
    }

    #[test]
    fn ring_only_spec_disables_chords_and_t_moves() {
        let spec = OptimizeSpec { t_min: 5, t_max: 5, max_degree: 2, ..Default::default() };
        let mut rng = Rng64::seed_from_u64(3);
        let start = random_genome(&mut rng, 8, &spec);
        assert_eq!(start.t, 5);
        for _ in 0..200 {
            let (g, kind) = propose(&start, &mut rng, 8, &spec).expect("ring moves always valid");
            assert!(kind == "two_opt" || kind == "or_opt", "unexpected move {kind}");
            assert_eq!(g.t, 5);
            assert!(g.chords.is_empty());
        }
    }

    #[test]
    fn random_genome_is_deterministic_in_seed() {
        let spec = spec();
        let a = random_genome(&mut Rng64::seed_from_u64(9), 11, &spec);
        let b = random_genome(&mut Rng64::seed_from_u64(9), 11, &spec);
        assert_eq!(a, b);
        let c = random_genome(&mut Rng64::seed_from_u64(10), 11, &spec);
        assert!(a != c || a.t != c.t, "different seeds should diverge");
    }
}
