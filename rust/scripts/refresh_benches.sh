#!/usr/bin/env bash
# Refresh every committed BENCH_*.json baseline in place, at full
# (paper-scale) settings, so each artifact carries `measured: true` and
# no null measurements. Run from anywhere; writes into rust/.
#
# Each bench asserts its identity gates and acceptance bar before
# writing its artifact, so a refreshed file is also a passed gate. CI
# never runs this (it smoke-runs the benches to /tmp instead); it exists
# for machines with the toolchain and the minutes to spare.
#
#   ./scripts/refresh_benches.sh            # all benches
#   ./scripts/refresh_benches.sh factored   # just one
set -euo pipefail
cd "$(dirname "$0")/.."

BENCHES=(simcore sweep_cache scaling factored batched store)
if [[ $# -gt 0 ]]; then
    BENCHES=("$@")
fi

for b in "${BENCHES[@]}"; do
    echo "== refreshing BENCH_${b}.json (cargo bench --bench ${b}) =="
    cargo bench --bench "$b"
done

# The same consistency check CI applies to the committed artifacts.
python3 - <<'EOF'
import glob, json, sys
bad = []
def nulls(x):
    if x is None:
        return 1
    if isinstance(x, dict):
        return sum(nulls(v) for v in x.values())
    if isinstance(x, list):
        return sum(nulls(v) for v in x)
    return 0
for path in sorted(glob.glob("BENCH_*.json")):
    obj = json.load(open(path))
    measured = obj.get("measured")
    if not isinstance(measured, bool):
        bad.append(f"{path}: `measured` must be a JSON boolean")
    elif measured and nulls(obj):
        bad.append(f"{path}: measured=true but null measurement(s) remain")
    elif not measured and not nulls(obj):
        bad.append(f"{path}: measured=false but no nulls left to fill in")
if bad:
    sys.exit("\n".join(bad))
print("all BENCH artifacts consistent")
EOF
